//! The `SocSystem` façade: typed run specifications in, structured —
//! machine-readable — reports out.
//!
//! Everything the CLI and benches used to do through stringly-typed free
//! functions (`stream_report(&str, usize, Option<&str>)`, ladder tuples,
//! inline `println!` rows) goes through three types here:
//!
//! * [`RunSpec`] — which [`crate::workload::Workload`], how many frames,
//!   which ladder [`Rung`] (by index, label substring, or best), and
//!   optional [`ModeOverrides`] on top (the ablation mechanism);
//! * [`SocSystem`] — resolves the spec against its workload [`Registry`],
//!   builds the frame graph, schedules it, and attributes the result
//!   (including per-tenant rows for multi-tenant workloads);
//! * [`RunReport`] / [`LadderReport`] / [`AblationReport`] — structured
//!   values that render to the exact text tables the CLI always printed
//!   *and* to JSON ([`crate::json`], hand-rolled — the crate stays
//!   anyhow-only).
//!
//! Multi-SoC scale-out lives here too: [`ShardedStream`] splits a frame
//! stream across S simulated Fulmine chips on `std::thread` workers (the
//! job-graph seam is the natural sharding boundary — frames are
//! independent, chips share nothing), and a [`RunSpec`] with
//! `shards > 1` returns the same [`RunReport`] with per-shard statistics
//! (simulated makespan, energy, and the `serialized_bound`/`analytic`
//! admission estimates) merged in: energy sums across chips, the
//! makespan is the slowest shard's, and throughput scales near-linearly.

use crate::coordinator::{
    share, stream_graph_windowed, ExecConfig, ModeOverrides, Rung, StreamResult, Tiling,
    UseCaseResult,
};
use crate::energy::{Category, EnergyLedger};
use crate::hwce::golden::WeightPrec;
use crate::json::Json;
use crate::soc::sched::{
    CompiledFrame, Engine, JobGraph, SchedResult, Scheduler, StreamScheduler, N_ENGINES,
};
use crate::workload::{frame_graph, Registry, Workload};
use anyhow::{anyhow, bail, Result};
use std::fmt::Write as _;
use std::time::Instant;

/// How a [`RunSpec`] selects a ladder rung.
#[derive(Debug, Clone, PartialEq)]
pub enum RungSel {
    /// The last (most accelerated) rung — the default.
    Best,
    /// By position on the workload's ladder.
    Index(usize),
    /// By case-insensitive label substring.
    Label(String),
}

impl RungSel {
    /// Parse a CLI `--config` selector: absent → best, an integer → index,
    /// anything else → label substring.
    pub fn parse(selector: Option<&str>) -> RungSel {
        match selector {
            None => RungSel::Best,
            Some(s) => match s.parse::<usize>() {
                Ok(i) => RungSel::Index(i),
                Err(_) => RungSel::Label(s.to_string()),
            },
        }
    }
}

/// A typed run request: the replacement for the stringly-typed
/// `stream_report` arguments.
#[derive(Debug, Clone)]
pub struct RunSpec {
    /// Registry name of the workload.
    pub workload: String,
    /// Frames to stream (1 = a single-frame run).
    pub frames: usize,
    pub rung: RungSel,
    /// Applied on top of the selected rung's configuration.
    pub overrides: ModeOverrides,
    /// In-flight frame window of the streaming scheduler
    /// ([`crate::soc::sched::DEFAULT_STREAM_WINDOW`] when `None`; clamped
    /// to the stream length). Live scheduler state is
    /// O(window × frame jobs) whatever `frames` is.
    pub window: Option<usize>,
    /// Simulated Fulmine chips to split the stream across (1 = one SoC,
    /// the default). With S > 1 the frames are sharded over S chips
    /// simulated on parallel host threads ([`ShardedStream`]) and the
    /// report carries per-shard statistics.
    pub shards: usize,
}

impl RunSpec {
    pub fn new(workload: &str) -> Self {
        RunSpec {
            workload: workload.to_string(),
            frames: 1,
            rung: RungSel::Best,
            overrides: ModeOverrides::default(),
            window: None,
            shards: 1,
        }
    }

    pub fn frames(mut self, frames: usize) -> Self {
        self.frames = frames;
        self
    }

    pub fn rung(mut self, rung: RungSel) -> Self {
        self.rung = rung;
        self
    }

    pub fn overrides(mut self, overrides: ModeOverrides) -> Self {
        self.overrides = overrides;
        self
    }

    pub fn window(mut self, window: usize) -> Self {
        self.window = Some(window);
        self
    }

    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }
}

/// Per-chip statistics of a sharded stream run ([`ShardedStream`]).
#[derive(Debug, Clone)]
pub struct ShardStat {
    /// Shard index (0..S).
    pub shard: usize,
    /// Frames this chip streamed (near-equal [`share`] split).
    pub frames: usize,
    /// Simulated makespan of this chip's stream (s).
    pub time_s: f64,
    /// Total energy this chip consumed (mJ).
    pub energy_mj: f64,
    pub mode_switches: u64,
    pub peak_resident_jobs: usize,
    /// Frames this chip's scheduler replayed through the steady-state
    /// fast-forward path.
    pub fast_forwarded_frames: usize,
    /// Host wall-clock spent simulating this shard (s) — the simulator's
    /// own cost, not simulated time.
    pub wall_s: f64,
    /// Admission estimate for this shard's share: the analytic
    /// (serialized-cluster) single-frame replay × frames.
    pub analytic_est_s: f64,
    /// Worst-case admission bound: [`JobGraph::serialized_bound`] × frames
    /// — no schedule of this shard can exceed it.
    pub serialized_bound_s: f64,
}

/// Frame-parallel multi-SoC scale-out: split a stream of identical frames
/// across S simulated Fulmine chips, one `std::thread` worker per chip.
/// The frame template is compiled once ([`CompiledFrame`]) and shared
/// read-only by every worker; each chip streams its [`share`] of the
/// frames through the bounded-window scheduler independently (chips share
/// nothing — the job-graph seam makes frames embarrassingly parallel, the
/// scaling axis multi-cluster endpoint SoCs like Vega take in hardware).
pub struct ShardedStream;

impl ShardedStream {
    /// Run `frames` split across `shards` chips (each chip streams its
    /// share with in-flight window `window`, clamped per shard). Returns
    /// per-shard scheduler results and statistics in shard order; shards
    /// is clamped to `frames` so no chip receives an empty stream.
    pub fn run(
        graph: &JobGraph,
        frames: usize,
        window: usize,
        shards: usize,
    ) -> Vec<(SchedResult, ShardStat)> {
        assert!(frames >= 1, "sharded streaming needs at least one frame");
        assert!(window >= 1, "sharded streaming needs at least one in-flight frame of window");
        assert!(shards >= 1, "sharded streaming needs at least one chip");
        let shards = shards.min(frames);
        let template = CompiledFrame::compile(graph);
        let analytic_s = graph.analytic().makespan_s;
        let bound_s = graph.serialized_bound();
        let shares: Vec<usize> = (0..shards).map(|s| share(frames, shards, s)).collect();
        let results: Vec<(SchedResult, f64)> = std::thread::scope(|scope| {
            let template = &template;
            let handles: Vec<_> = shares
                .iter()
                .map(|&f| {
                    scope.spawn(move || {
                        let t0 = Instant::now();
                        let r = StreamScheduler::run_compiled(template, f, window.min(f));
                        (r, t0.elapsed().as_secs_f64())
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard worker panicked"))
                .collect()
        });
        results
            .into_iter()
            .enumerate()
            .map(|(i, (r, wall_s))| {
                let stat = ShardStat {
                    shard: i,
                    frames: shares[i],
                    time_s: r.makespan_s,
                    energy_mj: r.ledger.total_mj(),
                    mode_switches: r.mode_switches,
                    peak_resident_jobs: r.peak_resident_jobs,
                    fast_forwarded_frames: r.fast_forwarded_frames,
                    wall_s,
                    analytic_est_s: analytic_s * shares[i] as f64,
                    serialized_bound_s: bound_s * shares[i] as f64,
                };
                (r, stat)
            })
            .collect()
    }
}

/// Merge per-shard scheduler results into one [`StreamResult`]: energy,
/// busy time, overlap and relocks sum across chips; the makespan is the
/// slowest shard's (chips run concurrently); peak residency is the
/// per-chip maximum (each chip bounds its own memory). Idle/standby
/// energy accrues per chip over *its own* makespan — a chip that drains
/// its share early enters deep sleep (§II power modes) rather than
/// leaking until the slowest shard finishes — which keeps the invariant
/// that the merged energy is exactly the sum of the shard energies.
fn merge_sharded(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
    parts: &[(SchedResult, ShardStat)],
) -> StreamResult {
    let single = Scheduler::run(graph);
    let analytic = graph.analytic();
    let mut ledger = EnergyLedger::new();
    let mut busy_s = [0.0f64; N_ENGINES];
    let (mut overlap_s, mut coresidency_s) = (0.0f64, 0.0f64);
    let mut mode_switches = 0u64;
    let (mut peak, mut total_jobs, mut ff) = (0usize, 0usize, 0usize);
    let mut time_s = 0.0f64;
    let mut max_share = 0usize;
    for (r, st) in parts {
        max_share = max_share.max(st.frames);
        ledger.merge(&r.ledger);
        for e in 0..N_ENGINES {
            busy_s[e] += r.busy_s[e];
        }
        overlap_s += r.overlap_s;
        coresidency_s += r.coresidency_s;
        mode_switches += r.mode_switches;
        peak = peak.max(r.peak_resident_jobs);
        total_jobs += r.n_jobs;
        ff += r.fast_forwarded_frames;
        time_s = time_s.max(r.makespan_s);
    }
    // chips run concurrently: elapsed time is the slowest shard, not the
    // sum `EnergyLedger::merge` accumulated
    ledger.elapsed_s = time_s;
    let energy_mj = ledger.total_mj();
    StreamResult {
        label: label.to_string(),
        frames,
        time_s,
        fps: frames as f64 / time_s,
        energy_mj,
        pj_per_op: energy_mj * 1e9 / (eq_ops_per_frame as f64 * frames as f64),
        single_frame_s: single.makespan_s,
        single_frame_analytic_s: analytic.makespan_s,
        speedup: single.makespan_s * frames as f64 / time_s,
        mode_switches,
        busy_s,
        overlap_s,
        coresidency_s,
        // each chip clamps to its own share; report the widest window any
        // shard actually ran with
        window: window.min(max_share),
        peak_resident_jobs: peak,
        total_jobs,
        fast_forwarded_frames: ff,
        ledger,
    }
}

/// Resolve a rung selector against a workload's ladder.
fn select_rung(rungs: &[Rung], sel: &RungSel) -> Result<Rung> {
    if rungs.is_empty() {
        bail!("workload declares no ladder rungs");
    }
    match sel {
        RungSel::Best => Ok(*rungs.last().expect("checked non-empty above")),
        RungSel::Index(i) => rungs
            .get(*i)
            .copied()
            .ok_or_else(|| anyhow!("rung index {i} out of range (0..{})", rungs.len())),
        RungSel::Label(sel) => {
            let needle = sel.to_lowercase();
            rungs
                .iter()
                .find(|r| r.label.to_lowercase().contains(&needle))
                .copied()
                .ok_or_else(|| {
                    let names: Vec<&str> = rungs.iter().map(|r| r.label).collect();
                    anyhow!("no rung matches {sel:?}; available: {names:?} or an index")
                })
        }
    }
}

/// Per-tenant attribution row of a [`RunReport`] (one row for ordinary
/// workloads; one per tenant for multi-tenant streams).
#[derive(Debug, Clone)]
pub struct TenantRow {
    pub name: String,
    /// OR1200-equivalent ops per frame of this tenant.
    pub eq_ops: u64,
    /// Active energy of this tenant's jobs over all frames (mJ).
    pub active_mj: f64,
    /// Active energy plus this tenant's proportional share of the
    /// schedule-wide idle/standby energy (mJ).
    pub energy_mj: f64,
    pub pj_per_op: f64,
}

/// Structured outcome of one [`SocSystem::run`]: everything the text
/// report shows, as data.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub workload: String,
    /// Label of the rung the run executed at.
    pub rung: String,
    /// The rung's configuration after overrides.
    pub cfg: ExecConfig,
    pub frames: usize,
    pub result: StreamResult,
    pub tenants: Vec<TenantRow>,
    /// Per-chip statistics of a sharded run (empty for a single SoC —
    /// the single-chip report is byte-identical to the unsharded one).
    pub shards: Vec<ShardStat>,
}

impl RunReport {
    /// The `fulmine stream` text report: throughput and energy as always,
    /// plus the per-engine utilization table (busy_s / makespan) and the
    /// overlap statistics of the schedule; multi-tenant runs add one
    /// attribution line per tenant.
    pub fn render_text(&self) -> String {
        let r = &self.result;
        let frames = self.frames;
        let mut s = String::new();
        writeln!(s, "== stream: {} @ {}, {frames} frames ==", self.workload, self.rung).unwrap();
        writeln!(
            s,
            "single frame {:>9.4} s | {frames} streamed {:>9.4} s  ({:.3} frames/s, {:.2}x vs back-to-back)",
            r.single_frame_s, r.time_s, r.fps, r.speedup
        )
        .unwrap();
        writeln!(
            s,
            "single-frame analytic bound {:>9.4} s (scheduled/analytic {:.3}x)",
            r.single_frame_analytic_s,
            r.single_frame_s / r.single_frame_analytic_s
        )
        .unwrap();
        writeln!(
            s,
            "energy {:>9.4} mJ total, {:>8.4} mJ/frame, {:>7.2} pJ/op | {} mode switches",
            r.energy_mj,
            r.energy_mj / frames as f64,
            r.pj_per_op,
            r.mode_switches
        )
        .unwrap();
        if self.tenants.len() > 1 {
            for t in &self.tenants {
                writeln!(
                    s,
                    "  tenant {:<14} {:>9.4} mJ  {:>7.2} pJ/op  ({:.3e} eq-ops/frame)",
                    t.name, t.energy_mj, t.pj_per_op, t.eq_ops as f64
                )
                .unwrap();
            }
        }
        // busy time sums across chips in a sharded run: normalize
        // utilization by chip-time (makespan × chips) so it stays ≤ 100 %
        // — a fleet average per engine type. S = 1 reduces to the
        // historical single-chip rendering unchanged.
        let chips = self.shards.len().max(1) as f64;
        writeln!(s, "{:<14} {:>10} {:>7}", "engine", "busy [s]", "util").unwrap();
        for e in Engine::ALL {
            let busy = r.busy_s[e.index()];
            if busy > 0.0 {
                writeln!(
                    s,
                    "{:<14} {:>10.4} {:>6.1}%",
                    e.name(),
                    busy,
                    busy / (r.time_s * chips) * 100.0
                )
                .unwrap();
            }
        }
        writeln!(
            s,
            "overlap {:>9.4} s (>=2 jobs in flight) | cluster co-residency {:>9.4} s",
            r.overlap_s, r.coresidency_s
        )
        .unwrap();
        writeln!(
            s,
            "window {} in-flight frames | peak resident jobs {} (of {} scheduled)",
            r.window, r.peak_resident_jobs, r.total_jobs
        )
        .unwrap();
        if !self.shards.is_empty() {
            writeln!(
                s,
                "sharded across {} SoCs (frame-parallel chips: energy/busy/overlap summed, makespan = slowest shard, util = fleet average)",
                self.shards.len()
            )
            .unwrap();
            for st in &self.shards {
                writeln!(
                    s,
                    "  shard {} {:>6} frames  {:>9.4} s  {:>9.4} mJ  analytic est {:>9.4} s  bound {:>9.4} s",
                    st.shard, st.frames, st.time_s, st.energy_mj, st.analytic_est_s, st.serialized_bound_s
                )
                .unwrap();
            }
        }
        writeln!(s, "{}", r.ledger.report(&format!("{} x{frames}", self.workload))).unwrap();
        s
    }

    pub fn to_json(&self) -> Json {
        let r = &self.result;
        // same chip-time normalization as the text report: per-chip
        // utilization for S = 1, fleet average per engine type otherwise
        let chips = self.shards.len().max(1) as f64;
        let mut engines = Vec::new();
        for e in Engine::ALL {
            let busy = r.busy_s[e.index()];
            if busy > 0.0 {
                engines.push(Json::obj(vec![
                    ("name", Json::string(e.name())),
                    ("busy_s", Json::num(busy)),
                    ("utilization", Json::num(busy / (r.time_s * chips))),
                ]));
            }
        }
        Json::obj(vec![
            ("workload", Json::string(&self.workload)),
            ("rung", Json::string(&self.rung)),
            ("frames", Json::num(self.frames as f64)),
            ("single_frame_s", Json::num(r.single_frame_s)),
            ("single_frame_analytic_s", Json::num(r.single_frame_analytic_s)),
            ("time_s", Json::num(r.time_s)),
            ("fps", Json::num(r.fps)),
            ("speedup_vs_serial", Json::num(r.speedup)),
            ("energy_mj", Json::num(r.energy_mj)),
            ("pj_per_op", Json::num(r.pj_per_op)),
            ("mode_switches", Json::num(r.mode_switches as f64)),
            ("overlap_s", Json::num(r.overlap_s)),
            ("coresidency_s", Json::num(r.coresidency_s)),
            ("window", Json::num(r.window as f64)),
            ("peak_resident_jobs", Json::num(r.peak_resident_jobs as f64)),
            ("total_jobs", Json::num(r.total_jobs as f64)),
            ("fast_forwarded_frames", Json::num(r.fast_forwarded_frames as f64)),
            ("shard_count", Json::num(self.shards.len().max(1) as f64)),
            (
                "shards",
                Json::Arr(
                    self.shards
                        .iter()
                        .map(|st| {
                            Json::obj(vec![
                                ("shard", Json::num(st.shard as f64)),
                                ("frames", Json::num(st.frames as f64)),
                                ("time_s", Json::num(st.time_s)),
                                ("energy_mj", Json::num(st.energy_mj)),
                                ("mode_switches", Json::num(st.mode_switches as f64)),
                                (
                                    "peak_resident_jobs",
                                    Json::num(st.peak_resident_jobs as f64),
                                ),
                                (
                                    "fast_forwarded_frames",
                                    Json::num(st.fast_forwarded_frames as f64),
                                ),
                                ("wall_s", Json::num(st.wall_s)),
                                ("analytic_est_s", Json::num(st.analytic_est_s)),
                                ("serialized_bound_s", Json::num(st.serialized_bound_s)),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("engines", Json::Arr(engines)),
            ("energy_breakdown_mj", breakdown_json(&r.ledger)),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("name", Json::string(&t.name)),
                                ("eq_ops_per_frame", Json::num(t.eq_ops as f64)),
                                ("active_mj", Json::num(t.active_mj)),
                                ("energy_mj", Json::num(t.energy_mj)),
                                ("pj_per_op", Json::num(t.pj_per_op)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn breakdown_json(ledger: &crate::energy::EnergyLedger) -> Json {
    Json::Obj(
        Category::all()
            .iter()
            .map(|&c| (c.name().to_string(), Json::num(ledger.energy_mj(c))))
            .collect(),
    )
}

/// One single-frame run per ladder rung of a workload.
#[derive(Debug, Clone)]
pub struct LadderReport {
    pub workload: String,
    pub rows: Vec<UseCaseResult>,
}

impl LadderReport {
    /// The Fig. 10/11/12-style table (the historical `ladder_table`
    /// rendering; `paper_note` appends the figure's comparison line).
    pub fn render_table(&self, title: &str, paper_note: Option<&str>) -> String {
        let mut s = String::new();
        writeln!(s, "== {title} ==").unwrap();
        writeln!(
            s,
            "{:<16} {:>9} {:>10} {:>8} | {:>8} {:>8} {:>8} {:>8} {:>8} {:>8}",
            "config", "time [s]", "E [mJ]", "pJ/op", "conv", "crypto", "o-sw", "dma", "extmem", "idle"
        )
        .unwrap();
        for r in &self.rows {
            write!(
                s,
                "{:<16} {:>9.4} {:>10.4} {:>8.2} |",
                r.label, r.time_s, r.energy_mj, r.pj_per_op
            )
            .unwrap();
            for c in Category::all() {
                write!(s, " {:>8.3}", r.ledger.energy_mj(c)).unwrap();
            }
            writeln!(s).unwrap();
        }
        if let Some(note) = paper_note {
            writeln!(s, "{note}").unwrap();
        }
        s
    }

    /// Generic rendering for `fulmine ladder <workload>`.
    pub fn render_text(&self) -> String {
        self.render_table(&format!("ladder: {}", self.workload), None)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::string(&self.workload)),
            (
                "rungs",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("label", Json::string(&r.label)),
                                ("time_s", Json::num(r.time_s)),
                                ("energy_mj", Json::num(r.energy_mj)),
                                ("eq_ops", Json::num(r.eq_ops as f64)),
                                ("pj_per_op", Json::num(r.pj_per_op)),
                                ("energy_breakdown_mj", breakdown_json(&r.ledger)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The surveillance design-choice sweep (ablation labels + results).
#[derive(Debug, Clone)]
pub struct AblationReport {
    pub rows: Vec<(String, UseCaseResult)>,
}

impl AblationReport {
    /// The historical `fulmine ablations` rows, one line each.
    pub fn render_text(&self) -> String {
        let mut s = String::new();
        for (label, r) in &self.rows {
            writeln!(
                s,
                "{label:<18} time {:>8.4} s  energy {:>8.3} mJ  {:>6.2} pJ/op",
                r.time_s, r.energy_mj, r.pj_per_op
            )
            .unwrap();
        }
        s
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "ablations",
            Json::Arr(
                self.rows
                    .iter()
                    .map(|(label, r)| {
                        Json::obj(vec![
                            ("label", Json::string(label)),
                            ("time_s", Json::num(r.time_s)),
                            ("energy_mj", Json::num(r.energy_mj)),
                            ("pj_per_op", Json::num(r.pj_per_op)),
                        ])
                    })
                    .collect(),
            ),
        )])
    }
}

/// The façade over one simulated Fulmine SoC: a workload [`Registry`] plus
/// the scheduling/attribution machinery to execute a [`RunSpec`].
pub struct SocSystem {
    registry: Registry,
}

impl Default for SocSystem {
    fn default() -> Self {
        Self::new()
    }
}

impl SocSystem {
    /// A system with the built-in workload set registered.
    pub fn new() -> Self {
        SocSystem { registry: Registry::builtin() }
    }

    /// A system over a caller-composed registry.
    pub fn with_registry(registry: Registry) -> Self {
        SocSystem { registry }
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    fn resolve(&self, spec: &RunSpec) -> Result<(&dyn Workload, Rung)> {
        let w = self.registry.resolve(&spec.workload)?;
        if spec.frames == 0 {
            bail!("--frames must be at least 1");
        }
        let mut rung = select_rung(&w.rungs(), &spec.rung)?;
        rung.cfg = spec.overrides.apply(rung.cfg);
        Ok((w, rung))
    }

    /// Schedule one frame of the spec's workload and return the Fig.
    /// 10/11/12-style result (the spec's `frames` is ignored here).
    pub fn run_frame(&self, spec: &RunSpec) -> Result<UseCaseResult> {
        let (w, rung) = self.resolve(spec)?;
        let g = frame_graph(w, rung.cfg)?;
        let res = Scheduler::run(&g);
        Ok(UseCaseResult::from_ledger(w.name(), res.ledger, w.eq_ops()))
    }

    /// Stream `spec.frames` frames of the workload through the scheduler
    /// (across `spec.shards` simulated chips when sharded) and return the
    /// structured report, with per-tenant attribution for multi-tenant
    /// workloads.
    pub fn run(&self, spec: &RunSpec) -> Result<RunReport> {
        let (w, rung) = self.resolve(spec)?;
        if spec.window == Some(0) {
            bail!("--window must be at least 1 (zero in-flight frames schedule nothing)");
        }
        if spec.shards == 0 {
            bail!("--shards must be at least 1 (no chips schedule no frames)");
        }
        let g = frame_graph(w, rung.cfg)?;
        let window = spec.window.unwrap_or(crate::soc::sched::DEFAULT_STREAM_WINDOW);
        let (result, shards) = if spec.shards > 1 {
            let parts = ShardedStream::run(&g, spec.frames, window, spec.shards);
            let result =
                merge_sharded(w.name(), &g, spec.frames, window, w.eq_ops(), &parts);
            (result, parts.into_iter().map(|(_, st)| st).collect())
        } else {
            (
                stream_graph_windowed(w.name(), &g, spec.frames, window, w.eq_ops()),
                Vec::new(),
            )
        };
        let frames = spec.frames as f64;

        // Per-tenant attribution. Rows follow the workload's *declared*
        // tenants (a tenant whose frame emitted no jobs still gets a row);
        // active energy is schedule-independent, so per-frame segment
        // totals — matched to tenants by name — scale by the frame count,
        // and the leftover (idle, leakage, ext-mem standby, plus any
        // segment matching no declared tenant) is shared out proportionally
        // to each tenant's active energy. Single-tenant workloads are one
        // row covering the whole schedule, whatever segments they marked.
        let seg = g.segment_active_mj();
        let tenant_info = w.tenants();
        let tenants = if seg.is_empty() || tenant_info.len() <= 1 {
            vec![TenantRow {
                name: w.name().to_string(),
                eq_ops: w.eq_ops(),
                active_mj: g.active_mj() * frames,
                energy_mj: result.energy_mj,
                pj_per_op: result.pj_per_op,
            }]
        } else {
            let active: Vec<f64> = tenant_info
                .iter()
                .map(|(name, _)| {
                    seg.iter().find(|(l, _)| l == name).map_or(0.0, |(_, mj)| mj * frames)
                })
                .collect();
            let active_total: f64 = active.iter().sum();
            let overhead = (result.energy_mj - active_total).max(0.0);
            tenant_info
                .iter()
                .zip(&active)
                .map(|((name, eq_ops), &active_mj)| {
                    let share = if active_total > 0.0 {
                        active_mj / active_total
                    } else {
                        1.0 / tenant_info.len() as f64
                    };
                    let energy_mj = active_mj + overhead * share;
                    // undefined rather than garbage when a tenant declares
                    // no equivalent ops (JSON renders NaN as null)
                    let pj_per_op = if *eq_ops > 0 {
                        energy_mj * 1e9 / (*eq_ops as f64 * frames)
                    } else {
                        f64::NAN
                    };
                    TenantRow {
                        name: name.clone(),
                        eq_ops: *eq_ops,
                        active_mj,
                        energy_mj,
                        pj_per_op,
                    }
                })
                .collect()
        };

        Ok(RunReport {
            workload: w.name().to_string(),
            rung: rung.label.to_string(),
            cfg: rung.cfg,
            frames: spec.frames,
            result,
            tenants,
            shards,
        })
    }

    /// One single-frame run per rung of the workload's ladder.
    pub fn ladder(&self, workload: &str) -> Result<LadderReport> {
        let w = self.registry.resolve(workload)?;
        let rows = w
            .rungs()
            .into_iter()
            .map(|rung| {
                let g = frame_graph(w, rung.cfg)?;
                let res = Scheduler::run(&g);
                let mut r = UseCaseResult::from_ledger(w.name(), res.ledger, w.eq_ops());
                r.label = rung.label.to_string();
                Ok(r)
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(LadderReport { workload: workload.to_string(), rows })
    }

    /// The Fig. 10 design-choice sweep, expressed as [`RunSpec`]s with
    /// [`ModeOverrides`] on the best surveillance rung — intermediate
    /// configurations not on the main ladder.
    pub fn surveillance_ablations(&self) -> Result<AblationReport> {
        let sweeps: [(&str, ModeOverrides); 5] = [
            (
                "hwce4+swcrypto",
                ModeOverrides { hwcrypt: Some(false), ..Default::default() },
            ),
            (
                "hwce8+hwcrypt",
                ModeOverrides { hwce: Some(Some(WeightPrec::W8)), ..Default::default() },
            ),
            ("hwce4@1.0V", ModeOverrides { vdd: Some(1.0), ..Default::default() }),
            ("hwce4@1.2V", ModeOverrides { vdd: Some(1.2), ..Default::default() }),
            (
                "hwce4 layer-gran",
                ModeOverrides { tiling: Some(Tiling::Layer), ..Default::default() },
            ),
        ];
        let mut rows = Vec::new();
        for (label, overrides) in sweeps {
            let spec = RunSpec::new("surveillance").overrides(overrides);
            rows.push((label.to_string(), self.run_frame(&spec)?));
        }
        Ok(AblationReport { rows })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rung_selection_modes() {
        let rungs = ExecConfig::ladder();
        assert_eq!(select_rung(&rungs, &RungSel::Best).unwrap().label, "+HWCE 4b");
        assert_eq!(select_rung(&rungs, &RungSel::Index(0)).unwrap().label, "SW 1-core");
        assert_eq!(
            select_rung(&rungs, &RungSel::Label("hwcrypt".into())).unwrap().label,
            "+HWCRYPT"
        );
        let e = select_rung(&rungs, &RungSel::Index(99)).unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let e = select_rung(&rungs, &RungSel::Label("nope".into())).unwrap_err().to_string();
        assert!(e.contains("available"), "{e}");
    }

    #[test]
    fn rungsel_parse_matches_cli_convention() {
        assert_eq!(RungSel::parse(None), RungSel::Best);
        assert_eq!(RungSel::parse(Some("2")), RungSel::Index(2));
        assert_eq!(RungSel::parse(Some("hwce")), RungSel::Label("hwce".into()));
    }

    #[test]
    fn zero_frames_rejected() {
        let sys = SocSystem::new();
        let e = sys.run(&RunSpec::new("surveillance").frames(0)).unwrap_err().to_string();
        assert!(e.contains("at least 1"), "{e}");
    }

    #[test]
    fn single_tenant_report_has_one_row() {
        let sys = SocSystem::new();
        let r = sys.run(&RunSpec::new("seizure").frames(2)).unwrap();
        assert_eq!(r.tenants.len(), 1);
        assert_eq!(r.tenants[0].name, "seizure");
        assert!((r.tenants[0].energy_mj - r.result.energy_mj).abs() < 1e-12);
        assert!(r.tenants[0].active_mj <= r.result.energy_mj + 1e-12);
    }

    #[test]
    fn zero_window_rejected() {
        let sys = SocSystem::new();
        let e = sys.run(&RunSpec::new("seizure").window(0)).unwrap_err().to_string();
        assert!(e.contains("--window must be at least 1"), "{e}");
    }

    #[test]
    fn zero_shards_rejected() {
        let sys = SocSystem::new();
        let e = sys.run(&RunSpec::new("seizure").shards(0)).unwrap_err().to_string();
        assert!(e.contains("--shards must be at least 1"), "{e}");
    }

    /// Satellite (window clamp): a window wider than the stream reports —
    /// and schedules — exactly as the clamped window does.
    #[test]
    fn oversized_window_clamps_and_matches() {
        let sys = SocSystem::new();
        let wide = sys.run(&RunSpec::new("seizure").frames(3).window(4096)).unwrap();
        let exact = sys.run(&RunSpec::new("seizure").frames(3).window(3)).unwrap();
        assert_eq!(wide.result.window, 3);
        assert_eq!(wide.result.time_s.to_bits(), exact.result.time_s.to_bits());
        assert_eq!(wide.result.energy_mj.to_bits(), exact.result.energy_mj.to_bits());
        assert_eq!(wide.result.peak_resident_jobs, exact.result.peak_resident_jobs);
    }

    /// Tentpole (multi-SoC sharding): splitting a stream across simulated
    /// chips sums energy, takes the slowest shard as the makespan, scales
    /// throughput near-linearly, and surfaces per-shard admission
    /// estimates that bound the scheduled makespans.
    #[test]
    fn sharded_stream_consistency() {
        let sys = SocSystem::new();
        let frames = 8usize;
        let base = sys.run(&RunSpec::new("seizure").frames(frames)).unwrap();
        let sharded = sys.run(&RunSpec::new("seizure").frames(frames).shards(2)).unwrap();
        assert_eq!(sharded.frames, frames);
        assert_eq!(sharded.shards.len(), 2);
        let f_sum: usize = sharded.shards.iter().map(|s| s.frames).sum();
        assert_eq!(f_sum, frames, "shard shares must partition the stream");
        let e_sum: f64 = sharded.shards.iter().map(|s| s.energy_mj).sum();
        assert!(
            (e_sum - sharded.result.energy_mj).abs() < 1e-9 * (1.0 + e_sum),
            "shard energies {e_sum} vs merged {}",
            sharded.result.energy_mj
        );
        assert!(
            sharded.result.time_s <= base.result.time_s + 1e-12,
            "sharding must not slow the stream"
        );
        assert!(
            sharded.result.fps >= base.result.fps * 1.5,
            "2 chips should approach 2x throughput: {} vs {}",
            sharded.result.fps,
            base.result.fps
        );
        for st in &sharded.shards {
            assert!(st.time_s <= st.serialized_bound_s + 1e-9, "shard {} bound", st.shard);
            assert!(st.analytic_est_s > 0.0 && st.frames > 0);
        }
        let text = sharded.render_text();
        assert!(text.contains("sharded across 2 SoCs"), "{text}");
        assert!(text.contains("shard 0") && text.contains("shard 1"), "{text}");
        let json = sharded.to_json().render();
        assert!(json.contains("\"shard_count\":2"), "{json}");
        assert!(json.contains("\"serialized_bound_s\""), "{json}");
        // a single-SoC report carries no shard section (byte-stable text)
        assert!(!base.render_text().contains("sharded across"), "S=1 text must be unchanged");
        assert_eq!(base.shards.len(), 0);
        // more chips than frames clamps to one frame per chip
        let over = sys.run(&RunSpec::new("seizure").frames(2).shards(16)).unwrap();
        assert_eq!(over.shards.len(), 2);
    }

    /// Satellite: per-tenant attribution is window-invariant — the active
    /// rows are identical for any window, and the attributed total always
    /// re-sums to the schedule's energy even though tighter windows may
    /// change the makespan (and with it the shared idle overhead).
    #[test]
    fn tenant_attribution_sums_are_window_invariant() {
        let sys = SocSystem::new();
        let frames = 6usize;
        let mut reference: Option<Vec<(String, f64)>> = None;
        for window in [1usize, 2, frames, 32] {
            let r = sys.run(&RunSpec::new("mixed").frames(frames).window(window)).unwrap();
            // oversized windows clamp to the stream length
            assert_eq!(r.result.window, window.min(frames));
            let attributed: f64 = r.tenants.iter().map(|t| t.energy_mj).sum();
            assert!(
                (attributed - r.result.energy_mj).abs() < 1e-6 * r.result.energy_mj,
                "window {window}: attributed {attributed} vs {}",
                r.result.energy_mj
            );
            let active: Vec<(String, f64)> =
                r.tenants.iter().map(|t| (t.name.clone(), t.active_mj)).collect();
            match &reference {
                None => reference = Some(active),
                Some(base) => {
                    for ((n0, a0), (n1, a1)) in base.iter().zip(&active) {
                        assert_eq!(n0, n1);
                        assert_eq!(a0.to_bits(), a1.to_bits(), "{n0} active energy vs window");
                    }
                }
            }
        }
    }
}
