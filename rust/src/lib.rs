//! # fulmine — a full-system software reproduction of the Fulmine SoC
//!
//! This crate reproduces *“An IoT Endpoint System-on-Chip for Secure and
//! Energy-Efficient Near-Sensor Analytics”* (Conti et al., IEEE TCSI 2017) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator and every hardware substrate the
//!   paper depends on, rebuilt in software: a cycle-approximate cluster
//!   simulator (TCDM banking, logarithmic interconnect, DMA, event unit), the
//!   HWCRYPT crypto engine (functional AES-128-ECB/XTS + KECCAK-f[400] sponge
//!   plus a datapath-derived cycle model), the HWCE convolution engine (golden
//!   fixed-point model + cycle model), a micro-ISA VM standing in for the
//!   OR10N cores, external flash/FRAM device models, and the SoC power
//!   manager with the paper's operating modes.
//! * **L2 (python/compile/model.py, build time only)** — quantized CNN graphs
//!   (ResNet-20, the 12-net/24-net face cascade) built on the L1 kernel and
//!   AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/hwce.py, build time only)** — a Pallas
//!   kernel mirroring the HWCE multi-precision fixed-point datapath.
//!
//! ## Execution model: job graphs on an event-driven scheduler
//!
//! The secure-analytics use cases of §IV ([`coordinator`]) do not sum phase
//! times analytically; they *emit job graphs at tile granularity*. A
//! [`coordinator::GraphBuilder`] turns each pipeline phase (convolution,
//! XTS/sponge cipher run, software kernel or epilogue, cluster-DMA stage,
//! external flash/FRAM/ADC transfer) into a typed [`soc::sched::Job`]
//! bound to a set of the SoC's engines — the four cluster cores
//! individually, the HWCE, the two HWCRYPT datapaths, the cluster DMA,
//! and per-interface uDMA channels — with explicit data dependencies;
//! layers split into TCDM-sized tiles
//! ([`coordinator::GraphBuilder::push_tiled`]) so a layer's L2↔TCDM and
//! external round trips pipeline within the layer. [`soc::sched::Scheduler`]
//! then advances simulated time through a binary-heap event queue: engines
//! execute one job at a time, and the cluster engines share one clock
//! under a *co-residency rule* — jobs whose modes are compatible under the
//! current point (the all-capable CRY-CNN-SW point hosts everything) run
//! concurrently, with the 10 µs FLL relock charged only on genuine
//! frequency changes — while the [`energy::EnergyLedger`] integrates
//! per-component power over each busy interval. Cross-engine concurrency —
//! double-buffered DMA, I/O prefetch under compute, next-tile weight
//! decryption and SW epilogues under the current convolution — *emerges
//! from the schedule*; the paper's per-phase cycle measurements (§III)
//! survive as each engine's service-time model, and
//! [`soc::sched::JobGraph::analytic`] keeps the old phase-summation model
//! as the calibration reference (scheduled energy stays within 5 % of it,
//! and the best-rung makespan closes below 1.15× of it; see
//! `rust/tests/scheduler.rs`).
//!
//! Streaming: [`soc::sched::StreamScheduler`] admits frame instances into
//! a rolling window of K in-flight frames (O(window) live jobs however
//! long the stream; bitwise identical to the materialized
//! [`soc::sched::JobGraph::repeat`] path when the window covers the
//! stream), and the scheduler pipelines them through the shared engines —
//! frame *f+1* fills the I/O stalls of frame *f*. Templates are lowered
//! once to struct-of-arrays [`soc::sched::CompiledFrame`] form (engine
//! bitmasks, CSR dependencies, prefolded energy rows), and once the
//! stream's schedule turns periodic the core **fast-forwards** it —
//! replaying the recorded steady-state decisions with pure accumulator
//! arithmetic, bitwise identical to live execution and verified each
//! cycle, falling back to live dispatch on any divergence. For scale-out,
//! [`system::ShardedStream`] splits a stream across S simulated chips on
//! parallel host threads (`fulmine stream --shards S`) with near-linear
//! throughput. The `fulmine stream` subcommand and `bench_scheduler`
//! report the resulting frames/s, pJ/op, engine utilization, peak
//! resident job count and fast-forwarded frame share.
//!
//! Frames need not arrive back-to-back: a [`traffic::Traffic`] model
//! (periodic, bursty, or seeded-Poisson — deterministic release tables,
//! no wall-clock) gates admission via
//! [`soc::sched::StreamScheduler::run_traffic`], and fast-forward still
//! engages on gap-dominated steady states (release waits are recorded
//! frame-relative and re-proven during replay). On top of that,
//! [`system::Fleet`] simulates entire *fleets*: a [`system::FleetSpec`]
//! describes per-chip populations over workload × rung × traffic
//! classes, identical chips dedup into classes simulated once and scaled
//! analytically to their population (via [`report::merge`]), with K
//! random members per class re-run live and checked **bitwise** against
//! the scaled representative — `fulmine fleet --chips 1000000` completes
//! in seconds and reports fleet-wide p50/p95/p99 energy, latency and
//! utilization percentiles ([`system::FleetReport`]).
//!
//! ## Public surface: workloads and the `SocSystem` façade
//!
//! Scenarios are first-class: anything the SoC can run implements
//! [`workload::Workload`] (name, description, graph emission, equivalent
//! op count, configuration ladder) and is resolved by name through a
//! [`workload::Registry`] — the three §IV use cases are registered
//! implementations, and `mixed` is a [`workload::MixedStream`] that
//! interleaves frames of all three on one SoC (per-tenant energy
//! attribution via graph segments). [`system::SocSystem`] executes a
//! typed [`system::RunSpec`] (workload, frames, ladder rung, mode
//! overrides) and returns structured reports ([`system::RunReport`],
//! [`system::LadderReport`], [`system::AblationReport`]) that render to
//! the paper's text tables and to JSON ([`json`], hand-rolled — the crate
//! stays anyhow-only). The [`cli`] module is a thin, testable command
//! layer over the façade.
//!
//! At runtime the rust binary loads `artifacts/*.hlo.txt` through the PJRT C
//! API ([`runtime`]; gated behind the `pjrt` feature, with an explanatory
//! stub in offline builds) and drives the simulated SoC through
//! [`coordinator`]; python never executes on the request path.
//!
//! See `ARCHITECTURE.md` at the repository root for the layer map and the
//! job-graph/scheduler design notes.

pub mod apps;
#[doc(hidden)]
pub mod bench_support;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod crypto;
pub mod energy;
pub mod extmem;
pub mod fault;
pub mod fixedpoint;
pub mod hwce;
pub mod hwcrypt;
pub mod isa;
pub mod json;
pub mod kernels_sw;
pub mod report;
pub mod runtime;
pub mod session;
pub mod soc;
pub mod system;
pub mod traffic;
pub mod workload;
