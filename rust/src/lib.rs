//! # fulmine — a full-system software reproduction of the Fulmine SoC
//!
//! This crate reproduces *“An IoT Endpoint System-on-Chip for Secure and
//! Energy-Efficient Near-Sensor Analytics”* (Conti et al., IEEE TCSI 2017) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator and every hardware substrate the
//!   paper depends on, rebuilt in software: a cycle-approximate cluster
//!   simulator (TCDM banking, logarithmic interconnect, DMA, event unit), the
//!   HWCRYPT crypto engine (functional AES-128-ECB/XTS + KECCAK-f[400] sponge
//!   plus a datapath-derived cycle model), the HWCE convolution engine (golden
//!   fixed-point model + cycle model), a micro-ISA VM standing in for the
//!   OR10N cores, external flash/FRAM device models, and the SoC power
//!   manager with the paper's operating modes.
//! * **L2 (python/compile/model.py, build time only)** — quantized CNN graphs
//!   (ResNet-20, the 12-net/24-net face cascade) built on the L1 kernel and
//!   AOT-lowered to HLO text artifacts.
//! * **L1 (python/compile/kernels/hwce.py, build time only)** — a Pallas
//!   kernel mirroring the HWCE multi-precision fixed-point datapath.
//!
//! At runtime the rust binary loads `artifacts/*.hlo.txt` through the PJRT C
//! API ([`runtime`]) and drives the simulated SoC through [`coordinator`];
//! python never executes on the request path.

pub mod apps;
#[doc(hidden)]
pub mod bench_support;
pub mod cluster;
pub mod coordinator;
pub mod crypto;
pub mod energy;
pub mod extmem;
pub mod fixedpoint;
pub mod hwce;
pub mod hwcrypt;
pub mod isa;
pub mod kernels_sw;
pub mod report;
pub mod runtime;
pub mod soc;
