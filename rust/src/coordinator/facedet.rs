//! §IV-B: local face detection with secured remote recognition — the
//! 12-net/24-net cascade on a 224×224 frame, entirely within L2 (no
//! external memories), plus full-frame AES-128-XTS encryption when a face
//! candidate is found (for transmission to the paired device).
//!
//! Each cascade stage is emitted at **tile granularity** over its window
//! batch: per TCDM-sized tile of windows, the DMA window staging, the
//! convolution (HWCE programmed from core 0) and the dense scoring layers
//! as a software epilogue on the cluster cores at the KEC-CNN-SW point —
//! so the dense layers of tile *t* co-reside with the convolution of tile
//! *t+1* instead of serializing through a SW-mode window. The 24-net
//! stage gates on every 12-net score (the candidate set is known only
//! then), and the encryption epilogue relocks to CRY-CNN-SW once at the
//! end. In streaming mode the next frame's staging and convolutions fill
//! the remaining stalls.

use super::{
    stream_graph, ExecConfig, Extent, GraphBuilder, RegionDeps, StreamResult, TiledConv,
    UseCaseResult, OR1200_FACTOR,
};
use crate::apps::facedet::*;
use crate::kernels_sw::crypto_cost::SW_AES_XTS_CPB_1CORE;
use crate::kernels_sw::dsp::DENSE_CYC_PER_MAC;
use crate::soc::sched::{JobGraph, JobId, Scheduler};

/// Naive scalar dense cost (no SIMD dot product): load-load-mac per element
/// plus loop overhead.
const NAIVE_DENSE_CYC_PER_MAC: f64 = 3.4;

/// Single-core cycles of `macs` dense-layer MACs (the epilogue splits them
/// across the cores).
fn dense_cycles_1core(macs: u64, cfg: &ExecConfig) -> f64 {
    let per_mac = if cfg.simd_sw { DENSE_CYC_PER_MAC } else { NAIVE_DENSE_CYC_PER_MAC };
    macs as f64 * per_mac
}

/// Emit one detection frame into an existing builder (the
/// [`crate::workload::Workload`] entry point; the configuration is the
/// builder's).
pub fn emit(b: &mut GraphBuilder) {
    let cfg = b.cfg;

    // Stage 1: 12-net over all windows, tiled to the TCDM. Conv on HWCE
    // (or SW); window extraction + dense layers on the cores.
    let c12 = conv_12net();
    let w12 = n_windows_12() as u64;
    let stage1_bytes = n_windows_12() * 12 * 12 * 2;
    let n1 = b.tiles(stage1_bytes);
    let spec1 = TiledConv {
        macs: w12 * c12.macs(),
        k: c12.k,
        stage_in_bytes: stage1_bytes,
        stage_out_bytes: 0, // scores stay resident in L1/L2
        epi_cycles_1core: dense_cycles_1core(w12 * dense_macs_12(), &cfg),
    };
    let t1 = b.push_tiled(n1, &spec1, &[]);

    // Stage 2: 24-net on the 10 % candidate windows. The candidate set is
    // known only once *every* 12-net tile has been scored (the selection
    // is global), so the stage boundary carries no region information:
    // the producer set is a [`RegionDeps::barrier`] and every stage-2
    // tile's `covering` resolves to all stage-1 tails — the documented
    // fallback when regions are unknown.
    let c24 = conv_24net();
    let w24 = n_windows_24() as u64;
    let stage2_bytes = n_windows_24() * 24 * 24 * 2;
    let n2 = b.tiles(stage2_bytes);
    let gate = RegionDeps::barrier(t1.tails());
    let deps2: Vec<Vec<JobId>> =
        (0..n2).map(|t| gate.covering(Extent::tile(t, n2))).collect();
    let spec2 = TiledConv {
        macs: w24 * c24.macs(),
        k: c24.k,
        stage_in_bytes: stage2_bytes,
        stage_out_bytes: 0,
        epi_cycles_1core: dense_cycles_1core(w24 * dense_macs_24(), &cfg),
    };
    let t2 = b.push_tiled(n2, &spec2, &deps2);

    // Detection epilogue: encrypt the full frame for remote recognition.
    b.xts(encrypted_image_bytes(), &t2.tails());
}

/// Emit the job graph of one detection frame.
pub fn frame_graph(cfg: ExecConfig) -> JobGraph {
    let mut b = GraphBuilder::new(cfg);
    emit(&mut b);
    b.build()
}

/// Run one frame of the detection pipeline through the scheduler.
pub fn run_frame(cfg: ExecConfig) -> UseCaseResult {
    let res = Scheduler::run(&frame_graph(cfg));
    UseCaseResult::from_ledger("facedet", res.ledger, eq_ops())
}

/// The pre-scheduler analytic reference of the same graph.
pub fn run_frame_analytic(cfg: ExecConfig) -> UseCaseResult {
    let res = frame_graph(cfg).analytic();
    UseCaseResult::from_ledger("facedet (analytic)", res.ledger, eq_ops())
}

/// Stream `frames` camera frames through the cascade.
pub fn run_stream(cfg: ExecConfig, frames: usize) -> StreamResult {
    stream_graph("facedet", &frame_graph(cfg), frames, eq_ops())
}

/// OR1200-equivalent ops for the §IV-B workload (baseline software).
pub fn eq_ops() -> u64 {
    let conv = (n_windows_12() as u64 * conv_12net().macs()) as f64 * 4.4
        + (n_windows_24() as u64 * conv_24net().macs()) as f64 * (94.0 / 25.0);
    let dense = total_dense_macs() as f64 * NAIVE_DENSE_CYC_PER_MAC;
    let crypto = encrypted_image_bytes() as f64 * SW_AES_XTS_CPB_1CORE;
    ((conv + dense + crypto) * OR1200_FACTOR) as u64
}

/// Run the Fig. 11 ladder.
pub fn ladder() -> Vec<UseCaseResult> {
    ExecConfig::ladder()
        .into_iter()
        .map(|rung| {
            let mut r = run_frame(rung.cfg);
            r.label = rung.label.to_string();
            r
        })
        .collect()
}

/// §IV-B battery-life estimate: continuous detection on a 4 V, 150 mA·h
/// smartwatch battery (paper: ≈1.6 days).
pub fn battery_days(r: &UseCaseResult) -> f64 {
    let battery_j = 4.0 * 0.150 * 3600.0;
    let frames = battery_j / (r.energy_mj / 1000.0);
    frames * r.time_s / 86400.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Tiling;
    use crate::soc::sched::Scheduler;

    /// Fig. 11 shape: ≈24× speedup and ≈13× energy vs the SW baseline.
    #[test]
    fn fig11_speedup_and_energy_shape() {
        let l = ladder();
        let speedup = l[0].time_s / l[4].time_s;
        let energy = l[0].energy_mj / l[4].energy_mj;
        // Paper: 24× / 13×. Our reconstruction is conv-heavier than the
        // (unpublished) exact cascade, so acceleration buys relatively more;
        // the direction and order of magnitude are the reproduced shape.
        assert!(speedup > 8.0 && speedup < 150.0, "speedup {speedup} (paper 24×)");
        assert!(energy > 5.0 && energy < 80.0, "energy ratio {energy} (paper 13×)");
    }

    /// Headline §IV-B numbers: ~0.57 mJ, ~5.74 pJ/op.
    #[test]
    fn fig11_absolute_bands() {
        let best = &ladder()[4];
        // Our cascade reconstruction is lighter than the paper's exact
        // (unpublished) Li-et-al. variant; pJ/op is normalized so it lands
        // in band, while absolute mJ scales with the op count.
        assert!(
            best.energy_mj > 0.03 && best.energy_mj < 2.5,
            "frame energy {} mJ (paper 0.57)",
            best.energy_mj
        );
        assert!(
            best.pj_per_op > 1.0 && best.pj_per_op < 15.0,
            "pJ/op {} (paper 5.74)",
            best.pj_per_op
        );
    }

    /// §IV-B: ≈1.6 days of continuous detection on a 150 mA·h battery.
    #[test]
    fn smartwatch_battery_band() {
        let best = &ladder()[4];
        let days = battery_days(best);
        assert!(days > 0.4 && days < 8.0, "battery days {days} (paper 1.6)");
    }

    /// §IV-B: SW optimizations help conv/dense much more than AES (XTS's
    /// tweak chain defeats parallelization) — crypto share must grow from
    /// rung 0 to rung 1, then collapse once HWCRYPT is enabled.
    #[test]
    fn crypto_share_dynamics() {
        use crate::energy::Category;
        let l = ladder();
        let share = |r: &UseCaseResult| r.ledger.energy_mj(Category::Crypto) / r.energy_mj;
        assert!(share(&l[1]) > share(&l[0]), "crypto share should grow with SW opt");
        assert!(share(&l[2]) < 0.5 * share(&l[1]), "HWCRYPT must collapse crypto share");
        // paper: accelerators reduce conv+crypto to <10 % of total
        let accel = &l[4];
        let combined = (accel.ledger.energy_mj(Category::Crypto)
            + accel.ledger.energy_mj(Category::Conv))
            / accel.energy_mj;
        assert!(combined < 0.75, "conv+crypto share {combined}");
    }

    #[test]
    fn no_external_memory_traffic() {
        use crate::energy::Category;
        let r = run_frame(ExecConfig::with_hwce(crate::hwce::golden::WeightPrec::W4));
        // only standby ext-mem power, no active transfers
        let ext = r.ledger.energy_mj(Category::ExtMem);
        assert!(ext < 0.15 * r.energy_mj, "ext-mem standby share {ext}");
    }

    /// Tiling the window batches lets the dense scoring of tile *t*
    /// co-reside with the convolution of tile *t+1*: the tiled schedule
    /// must beat the layer-granular one.
    #[test]
    fn tiled_beats_layer_granular() {
        let best = ExecConfig::ladder().last().unwrap().cfg;
        let tiled = Scheduler::run(&frame_graph(best));
        let layer = Scheduler::run(&frame_graph(ExecConfig { tiling: Tiling::Layer, ..best }));
        assert!(
            tiled.makespan_s < 0.95 * layer.makespan_s,
            "tiled {} vs layer-granular {}",
            tiled.makespan_s,
            layer.makespan_s
        );
        assert!(tiled.coresidency_s > 0.0, "conv and dense epilogues must co-reside");
    }

    // The scheduled-vs-analytic 5 % calibration and the streaming
    // never-slower contracts are asserted centrally, across all use cases
    // and rungs, in rust/tests/scheduler.rs.
}
