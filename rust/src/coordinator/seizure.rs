//! §IV-C: EEG seizure detection with secure long-term monitoring — PCA →
//! DWT → energy coefficients → SVM every 0.5 s (256 Hz sampling, 50 %
//! overlapped 256-sample windows), with AES-128-XTS encryption of the PCA
//! components for collection.
//!
//! The window graph streams the acquisition over the dedicated ADC uDMA
//! channel in chunks, with the covariance accumulation pipelining behind
//! each chunk (the analytics no longer wait for the full window to land).
//! The remaining pipeline stages run on the cores with the serial/parallel
//! split of the cycle model ([`eeg_cost::EegOpCounts`]): Jacobi
//! diagonalization (rotation search serial, row/column updates parallel),
//! projection, DWT, SVM. The XTS encryption of the collected components
//! depends only on the projection (the components exist then), so in
//! streaming mode it overlaps the next window's acquisition and analytics;
//! the cluster relocks once to CRY-CNN-SW per window, as the real device
//! does between its 0.5 s deadlines.

use super::{
    share, stream_graph, ExecConfig, Extent, GraphBuilder, RegionDeps, Rung, StreamResult, Tiling,
    UseCaseResult, OR1200_FACTOR,
};
use crate::apps::eeg;
use crate::kernels_sw::crypto_cost::SW_AES_XTS_CPB_1CORE;
use crate::kernels_sw::eeg_cost::{self, CYC_PER_OP_PARALLEL, CYC_PER_OP_SERIAL};
use crate::soc::sched::{JobGraph, JobId, Scheduler};

/// Seconds between windows (50 % overlap at 256 Hz).
pub const WINDOW_PERIOD_S: f64 = 0.5;

/// Acquisition chunks per window under tiled emission: the ADC uDMA
/// delivers channel groups while the covariance accumulation consumes
/// them.
pub const ACQ_CHUNKS: usize = 4;

/// Emit one detection window into an existing builder (the
/// [`crate::workload::Workload`] entry point; the configuration is the
/// builder's).
pub fn emit(b: &mut GraphBuilder) {
    b.set_ext_mem_present(false); // pacemaker-class node: no flash/FRAM
    let ops = eeg_cost::EegOpCounts::standard();
    // acquire samples (23 ch × 128 new samples × 4 B) over the dedicated
    // ADC uDMA channel, in chunks; the covariance accumulation of chunk t
    // starts as soon as chunk t has landed.
    let acq_bytes = eeg_cost::N_CHANNELS * 128 * 4;
    let n = if b.cfg.tiling == Tiling::Layer { 1 } else { ACQ_CHUNKS };
    let cov_cycles = ops.covariance as f64 * CYC_PER_OP_PARALLEL;
    // The acquisition chunks carry their sample extents: each covariance
    // accumulation chunk region-matches exactly the ADC burst that
    // produced its channel group (a 1:1 [`RegionDeps`] mapping — the
    // degenerate but type-checked case of the layer-boundary matching).
    let acq = RegionDeps::tiled(
        (0..n).map(|t| (b.adc(share(acq_bytes, n, t), &[]), Extent::tile(t, n))).collect(),
    );
    let mut cov: Vec<JobId> = Vec::with_capacity(n);
    for t in 0..n {
        let deps = acq.covering(Extent::tile(t, n));
        cov.push(b.sw_split(0.0, cov_cycles / n as f64, &deps));
    }
    // Jacobi eigendecomposition: the rotation search is serial, the
    // row/column updates parallelize (the §IV-C 2.6× four-core band)
    let diag_serial_ops = ops.diagonalization / 4;
    let diag = b.sw_split(
        diag_serial_ops as f64 * CYC_PER_OP_SERIAL,
        (ops.diagonalization - diag_serial_ops) as f64 * CYC_PER_OP_PARALLEL,
        &cov,
    );
    // projection onto the principal components — the collected data
    let proj = b.sw_split(0.0, ops.projection as f64 * CYC_PER_OP_PARALLEL, &[diag]);
    // DWT + energy coefficients + SVM classification
    let dwt = b.sw_split(0.0, ops.dwt as f64 * CYC_PER_OP_PARALLEL, &[proj]);
    b.sw_split(ops.svm as f64 * CYC_PER_OP_SERIAL, 0.0, &[dwt]);
    // encrypt the PCA components for secure collection: ready once the
    // projection exists, independent of the classification tail
    b.xts(eeg::collected_bytes(), &[proj]);
}

/// Emit the job graph of one detection window.
pub fn window_graph(cfg: ExecConfig) -> JobGraph {
    let mut b = GraphBuilder::new(cfg);
    emit(&mut b);
    b.build()
}

/// Run one detection window at the given configuration through the
/// scheduler.
pub fn run_window(cfg: ExecConfig) -> UseCaseResult {
    let res = Scheduler::run(&window_graph(cfg));
    UseCaseResult::from_ledger("seizure", res.ledger, eq_ops())
}

/// The pre-scheduler analytic reference of the same graph.
pub fn run_window_analytic(cfg: ExecConfig) -> UseCaseResult {
    let res = window_graph(cfg).analytic();
    UseCaseResult::from_ledger("seizure (analytic)", res.ledger, eq_ops())
}

/// Stream `frames` consecutive windows through the scheduler.
pub fn run_stream(cfg: ExecConfig, frames: usize) -> StreamResult {
    stream_graph("seizure", &window_graph(cfg), frames, eq_ops())
}

/// OR1200-equivalent ops for one window (baseline software).
pub fn eq_ops() -> u64 {
    let pipeline = eeg_cost::eeg_pipeline_cycles(1) as f64;
    let crypto = eeg::collected_bytes() as f64 * SW_AES_XTS_CPB_1CORE;
    ((pipeline + crypto) * OR1200_FACTOR) as u64
}

/// The Fig. 12 rungs: software scaling then accelerated encryption (the
/// HWCE plays no role — there are no convolutions).
pub fn rung_configs() -> Vec<Rung> {
    vec![
        Rung { label: "SW 1-core", cfg: ExecConfig::sw_1core() },
        Rung {
            label: "SW 4-core",
            cfg: ExecConfig { simd_sw: false, ..ExecConfig::sw_4core_simd() },
        },
        Rung {
            label: "4-core+HWCRYPT",
            cfg: ExecConfig { simd_sw: false, ..ExecConfig::with_hwcrypt() },
        },
    ]
}

/// The Fig. 12 ladder.
pub fn ladder() -> Vec<UseCaseResult> {
    rung_configs()
        .into_iter()
        .map(|rung| {
            let mut r = run_window(rung.cfg);
            r.label = rung.label.to_string();
            r
        })
        .collect()
}

/// §IV-C battery math: iterations on a 2 A·h @ 3.3 V pacemaker battery and
/// continuous-use days (paper: >130 M iterations, >750 days continuous).
pub fn pacemaker_endurance(r: &UseCaseResult) -> (f64, f64) {
    let battery_j = 2.0 * 3.3 * 3600.0;
    let iters = battery_j / (r.energy_mj / 1000.0);
    // continuous use: one window each WINDOW_PERIOD_S; between windows the
    // SoC deep-sleeps (Table I: 120 µW SOC, <0.01 µW power-gated cluster)
    let sleep_mw = 0.12 + 0.00001;
    let e_per_period = r.energy_mj + sleep_mw * (WINDOW_PERIOD_S - r.time_s).max(0.0);
    let days = battery_j / (e_per_period / 1000.0) * WINDOW_PERIOD_S / 86400.0;
    (iters, days)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Fig. 12 shape: combined parallelization + HWCRYPT ⇒ ≈4.3× speedup
    /// and ≈2.1× energy reduction.
    #[test]
    fn fig12_speedup_and_energy_shape() {
        let l = ladder();
        assert_eq!(l.len(), 3);
        let speedup = l[0].time_s / l[2].time_s;
        let energy = l[0].energy_mj / l[2].energy_mj;
        assert!(speedup > 2.0 && speedup < 8.0, "speedup {speedup} (paper 4.3×)");
        assert!(energy > 1.3 && energy < 4.0, "energy ratio {energy} (paper 2.1×)");
    }

    /// Headline §IV-C numbers: ~0.18 mJ/window, ~12.7 pJ/op.
    #[test]
    fn fig12_absolute_bands() {
        let best = &ladder()[2];
        // Our EEG op-count model is leaner than the cited [30] implementation
        // (≈2 M vs ≈14 M equivalent ops/window), so absolute energy scales
        // down proportionally — the normalized pJ/op metric is the anchor.
        assert!(
            best.energy_mj > 0.005 && best.energy_mj < 0.8,
            "window energy {} mJ (paper 0.18 at ≈7× our op count)",
            best.energy_mj
        );
        assert!(
            best.pj_per_op > 4.0 && best.pj_per_op < 30.0,
            "pJ/op {} (paper 12.7)",
            best.pj_per_op
        );
    }

    /// §IV-C: encryption "essentially disappears" with the HWCRYPT.
    #[test]
    fn crypto_transparent_with_hwcrypt() {
        use crate::energy::Category;
        let l = ladder();
        let share = |r: &UseCaseResult| r.ledger.energy_mj(Category::Crypto) / r.energy_mj;
        assert!(share(&l[2]) < 0.10, "crypto share {} must be near zero", share(&l[2]));
        assert!(share(&l[0]) > share(&l[2]) * 2.0);
    }

    /// §IV-C: pacemaker battery sustains >100 M iterations / >500 days.
    #[test]
    fn pacemaker_endurance_band() {
        let best = &ladder()[2];
        let (iters, days) = pacemaker_endurance(best);
        assert!(iters > 5e7, "iterations {iters} (paper >130e6)");
        assert!(days > 200.0, "continuous days {days} (paper >750)");
    }

    /// Real-time constraint: a window must complete well within its 0.5 s
    /// period in every configuration.
    #[test]
    fn real_time_feasible_everywhere() {
        for r in ladder() {
            assert!(r.time_s < WINDOW_PERIOD_S, "{}: {} s", r.label, r.time_s);
        }
    }

    /// The staged pipeline must cost exactly the lump cycle model: the
    /// per-stage serial/parallel split re-sums to
    /// [`eeg_cost::eeg_pipeline_cycles`].
    #[test]
    fn staged_emission_matches_lump_cycle_model() {
        for n_cores in [1usize, 4] {
            let ops = eeg_cost::EegOpCounts::standard();
            let n = n_cores as f64;
            let diag_serial = ops.diagonalization / 4;
            let staged = ops.covariance as f64 * CYC_PER_OP_PARALLEL / n
                + diag_serial as f64 * CYC_PER_OP_SERIAL
                + (ops.diagonalization - diag_serial) as f64 * CYC_PER_OP_PARALLEL / n
                + ops.projection as f64 * CYC_PER_OP_PARALLEL / n
                + ops.dwt as f64 * CYC_PER_OP_PARALLEL / n
                + ops.svm as f64 * CYC_PER_OP_SERIAL;
            let lump = eeg_cost::eeg_pipeline_cycles(n_cores) as f64;
            assert!(
                (staged - lump).abs() <= 1.0,
                "{n_cores} cores: staged {staged} vs lump {lump}"
            );
        }
    }

    /// Chunked acquisition pipelines under the covariance accumulation:
    /// the tiled window is strictly faster than the layer-granular one
    /// (by most of the acquisition latency).
    #[test]
    fn tiled_acquisition_beats_layer_granular() {
        let best = rung_configs().pop().unwrap().cfg;
        let tiled = Scheduler::run(&window_graph(best)).makespan_s;
        let layer =
            Scheduler::run(&window_graph(ExecConfig { tiling: Tiling::Layer, ..best })).makespan_s;
        assert!(tiled < layer, "tiled {tiled} vs layer-granular {layer}");
    }

    /// Streamed windows stay within the 0.5 s real-time budget per window
    /// (the ≤ N× back-to-back bound itself is asserted centrally in
    /// rust/tests/scheduler.rs, as is the 5 % analytic calibration).
    #[test]
    fn streaming_windows_real_time() {
        let cfg = rung_configs().pop().unwrap().cfg;
        let r = run_stream(cfg, 16);
        assert!(r.time_s / 16.0 < WINDOW_PERIOD_S, "streamed window period {}", r.time_s / 16.0);
    }
}
