//! §IV-A: secure autonomous aerial surveillance — ResNet-20 on 224×224
//! frames with AES-128-XTS protection of all weights (flash) and partial
//! results (FRAM); the cluster is the only secure enclave.
//!
//! Each layer is emitted at **tile granularity**: per TCDM-sized tile, the
//! weight fetch (flash uDMA channel, prefetchable from frame start), the
//! partial-result fetch from FRAM, the XTS decrypts on the HWCRYPT, the
//! L2→TCDM DMA stage, the convolution (HWCE programmed from core 0, or the
//! software cores) with its bias/ReLU/pool epilogue on the cores, then the
//! XTS re-encrypt, TCDM→L2 stage and FRAM store of the tile's results.
//! Because every tile chains only through its own data, the FRAM round
//! trip of tile *t* pipelines under the convolution of tile *t±1* —
//! double buffering *within* the layer, not just across frames.
//!
//! Layer boundaries are **region-matched** ([`RegionDeps`]): each tile's
//! FRAM store carries its output extent, and layer *i+1*'s tile fetches
//! depend only on the producer tiles covering their halo-dilated input
//! rows — so the first tiles of the next layer start their FRAM round
//! trip while the previous layer is still convolving and storing its last
//! tiles, instead of barriering on the whole layer. Extents are emitted
//! as full-width row bands (the [`Extent::tile`] 1-D fallback): the
//! TCDM-sized working sets split these layers into only 6–13 tiles —
//! often a prime count — where a row×column grid would *widen* the
//! average halo fan-in (a middle grid cell touches its 3×3 neighbourhood,
//! 9 producers, vs ≤ 5 for a haloed band). The 2-D [`Extent::grid`] path
//! exists for finer tilings and is pinned by the region tests in
//! `coordinator`; the band bound here is asserted at ≤ 5 producers per
//! fetch.
//!
//! When both accelerators are configured the emission pins the cluster at
//! the all-capable CRY-CNN-SW point ([`GraphBuilder::set_cluster_point`]):
//! HWCE convolution, HWCRYPT cipher runs and SW epilogues then co-reside
//! on one clock with zero FLL relocks — the §II-D overlap the paper's
//! best-rung numbers assume — trading the KEC-mode frequency margin for
//! full concurrency. In streaming mode the next frame additionally fills
//! whatever stalls remain.

use super::{
    share, stream_graph, ExecConfig, Extent, GraphBuilder, RegionDeps, StreamResult, TiledConv,
    UseCaseResult, NAIVE_CYC_PER_MAC_3, OR1200_FACTOR,
};
use crate::apps::resnet::{self, ConvLayer};
use crate::extmem::Device;
use crate::hwce::golden::WeightPrec;
use crate::kernels_sw::crypto_cost::SW_AES_XTS_CPB_1CORE;
use crate::kernels_sw::dsp::{MAXPOOL_CYC_PER_OUT, RELU_CYC_PER_ELEM};
use crate::soc::opmodes::OperatingMode;
use crate::soc::sched::{JobGraph, JobId, Scheduler};

/// Per-element software cost of the bias+ReLU epilogue (load, add-sat,
/// relu, store — matches the VM dsp kernels).
const EPILOGUE_CYC_PER_ELEM: f64 = RELU_CYC_PER_ELEM + 1.0;

/// Cycles of the classifier head (global average pool + fc on the cores).
const HEAD_CYCLES: f64 = 20_000.0;

fn layer_epilogue_cycles(l: &ConvLayer) -> f64 {
    let dense_out = (l.cout * l.h * l.w) as f64;
    let mut c = dense_out * EPILOGUE_CYC_PER_ELEM;
    if l.pool > 1 {
        let (oh, ow) = l.out_dims();
        c += (l.cout * oh * ow) as f64 * MAXPOOL_CYC_PER_OUT * (l.pool / 2) as f64;
    }
    c
}

/// Emit one secure ResNet-20 frame into an existing builder (the
/// [`crate::workload::Workload`] entry point; the configuration is the
/// builder's).
pub fn emit(b: &mut GraphBuilder) {
    let layers = resnet::resnet20_224();
    // Storage precision follows the HWCE mode (W4 shrinks flash traffic, as
    // §IV-A exploits); software rungs use the 16-bit baseline format.
    let store_prec = b.cfg.hwce.unwrap_or(WeightPrec::W16);
    // Steady state interleaves HWCE and HWCRYPT work on every tile: pin
    // the cluster at the all-capable point so they co-reside (§II-D).
    if b.cfg.hwce.is_some() && b.cfg.hwcrypt {
        b.set_cluster_point(OperatingMode::CryCnnSw);
    }

    // FRAM stores of the previous layer's output tiles, with their output
    // extents: the next layer's input fetches wait only for the producer
    // tiles covering their (halo-dilated) input region, so layer *i+1*
    // starts fetching while layer *i* is still storing its last tiles.
    let mut prev_stores = RegionDeps::none();
    let mut last_tails: Vec<JobId> = Vec::new();
    for (i, l) in layers.iter().enumerate() {
        let wb = l.weight_bytes(store_prec);
        let in_b = l.in_bytes();
        let out_b = l.out_bytes();
        // tile count from the layer's TCDM working set: input slice +
        // weight slice + output buffer
        let n = b.tiles(in_b + wb + out_b);
        // rows the k×k window reads beyond a tile's own rows, as a
        // fraction of the layer's input height
        let halo = ((l.k - 1) / 2) as f64 / l.h as f64;

        // per-tile operand production: weights flash→L2 (prefetchable from
        // frame start) and decrypt; partial results FRAM→L2 and decrypt
        // (all but the first layer, whose input is the camera frame
        // already in L2)
        let mut deps: Vec<Vec<JobId>> = Vec::with_capacity(n);
        for t in 0..n {
            let w_fetch = b.extmem(Device::Flash, share(wb, n, t), &[]);
            let w_dec = b.xts(share(wb, n, t), &[w_fetch]);
            let mut d = vec![w_dec];
            if i > 0 {
                let region = Extent::tile(t, n).dilate(halo);
                let producers = prev_stores.covering(region);
                let in_fetch = b.extmem(Device::Fram, share(in_b, n, t), &producers);
                d.push(b.xts(share(in_b, n, t), &[in_fetch]));
            }
            deps.push(d);
        }

        // staged tile pipeline: DMA in → conv → epilogue, per tile
        let spec = TiledConv {
            macs: l.macs(),
            k: l.k,
            stage_in_bytes: in_b + wb,
            stage_out_bytes: 0, // the encrypt-store chain below stages out
            epi_cycles_1core: layer_epilogue_cycles(l),
        };
        let tiled = b.push_tiled(n, &spec, &deps);

        // results: per tile encrypt → stage back → store to FRAM, each
        // store tagged with its tile's output extent for the next layer
        prev_stores = RegionDeps::tiled(
            (0..n)
                .map(|t| {
                    let enc = b.xts(share(out_b, n, t), &[tiled.tail(t)]);
                    let out_dma = b.dma(share(out_b, n, t), &[enc]);
                    let store = b.extmem(Device::Fram, share(out_b, n, t), &[out_dma]);
                    (store, tiled.out_extents[t])
                })
                .collect(),
        );
        last_tails = tiled.tails();
    }
    // classifier head on the last layer's activations (still in the
    // cluster) — it needs every tile of the final layer
    b.sw(HEAD_CYCLES, 1.0, &last_tails);
}

/// Emit the job graph of one secure ResNet-20 frame.
pub fn frame_graph(cfg: ExecConfig) -> JobGraph {
    let mut b = GraphBuilder::new(cfg);
    emit(&mut b);
    b.build()
}

/// Run one secure ResNet-20 frame at the given configuration through the
/// event-driven scheduler.
pub fn run_frame(cfg: ExecConfig) -> UseCaseResult {
    let res = Scheduler::run(&frame_graph(cfg));
    UseCaseResult::from_ledger("surveillance", res.ledger, eq_ops())
}

/// The pre-scheduler analytic reference (phase summation + I/O backlog) of
/// the same graph — the model the Fig. 10 bands were calibrated against.
pub fn run_frame_analytic(cfg: ExecConfig) -> UseCaseResult {
    let res = frame_graph(cfg).analytic();
    UseCaseResult::from_ledger("surveillance (analytic)", res.ledger, eq_ops())
}

/// Stream `frames` successive frames through the scheduler (§IV-A run
/// continuously over a flight).
pub fn run_stream(cfg: ExecConfig, frames: usize) -> StreamResult {
    stream_graph("surveillance", &frame_graph(cfg), frames, eq_ops())
}

/// OpenRISC-1200-equivalent operations of the §IV-A workload (definition
/// footnote 4): baseline software instruction count for the full task at
/// the 16-bit storage format.
pub fn eq_ops() -> u64 {
    let layers = resnet::resnet20_224();
    let conv: f64 = layers
        .iter()
        .map(|l| l.macs() as f64 * NAIVE_CYC_PER_MAC_3)
        .sum();
    let crypto_bytes: f64 = layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            l.weight_bytes(WeightPrec::W16) as f64
                + l.out_bytes() as f64
                + if i > 0 { l.in_bytes() as f64 } else { 0.0 }
        })
        .sum();
    let crypto = crypto_bytes * SW_AES_XTS_CPB_1CORE;
    let other: f64 = layers.iter().map(layer_epilogue_cycles).sum::<f64>() + HEAD_CYCLES;
    ((conv + crypto + other) * OR1200_FACTOR) as u64
}

/// Run the whole Fig. 10 ladder.
pub fn ladder() -> Vec<UseCaseResult> {
    ExecConfig::ladder()
        .into_iter()
        .map(|rung| {
            let mut r = run_frame(rung.cfg);
            r.label = rung.label.to_string();
            r
        })
        .collect()
}

/// §IV-A flight-time feasibility: iterations of the secure ResNet-20 over a
/// 7-minute CrazyFlie flight, and the battery fraction consumed (2590 J).
pub fn flight_feasibility(r: &UseCaseResult) -> (u64, f64) {
    let flight_s = 7.0 * 60.0;
    let iters = (flight_s / r.time_s).floor() as u64;
    let energy_j = iters as f64 * r.energy_mj / 1000.0;
    (iters, energy_j / 2590.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Tiling;

    #[test]
    fn ladder_monotone_time_and_energy() {
        let l = ladder();
        assert_eq!(l.len(), 5);
        for i in 1..l.len() {
            assert!(
                l[i].time_s < l[i - 1].time_s * 1.02,
                "time not improving at rung {i}: {} vs {}",
                l[i].time_s,
                l[i - 1].time_s
            );
        }
        assert!(l[4].energy_mj < l[0].energy_mj);
    }

    /// Fig. 10 shape: full acceleration is ≳50× faster and ≳20× more
    /// efficient than the single-core software baseline (paper: 114×/45×).
    #[test]
    fn fig10_speedup_and_energy_shape() {
        let l = ladder();
        let speedup = l[0].time_s / l[4].time_s;
        let energy_ratio = l[0].energy_mj / l[4].energy_mj;
        assert!(speedup > 50.0, "speedup {speedup} (paper 114×)");
        assert!(energy_ratio > 15.0, "energy ratio {energy_ratio} (paper 45×)");
    }

    /// Headline §IV-A numbers: ~27 mJ, ~3.16 pJ/op at the best rung.
    #[test]
    fn fig10_absolute_energy_band() {
        let best = &ladder()[4];
        assert!(
            best.energy_mj > 8.0 && best.energy_mj < 80.0,
            "frame energy {} mJ (paper 27 mJ)",
            best.energy_mj
        );
        assert!(
            best.pj_per_op > 1.0 && best.pj_per_op < 10.0,
            "pJ/op {} (paper 3.16)",
            best.pj_per_op
        );
    }

    /// §IV-A: continuous execution over a 7-minute flight must consume a
    /// negligible fraction of the 2590 J battery (paper: <0.25 %, 235 iters).
    #[test]
    fn flight_feasibility_negligible_battery() {
        let best = &ladder()[4];
        let (iters, frac) = flight_feasibility(best);
        assert!(iters > 100, "iterations {iters} (paper 235)");
        assert!(frac < 0.01, "battery fraction {frac} (paper <0.0025)");
    }

    /// In the best configuration the external memories are a large share —
    /// §IV-A: FRAM alone >30 % of total energy, cluster ≈50 %.
    #[test]
    fn extmem_share_grows_with_acceleration() {
        use crate::energy::Category;
        let l = ladder();
        let share = |r: &UseCaseResult| r.ledger.energy_mj(Category::ExtMem) / r.energy_mj;
        assert!(share(&l[4]) > share(&l[0]), "ext-mem share must grow");
        assert!(share(&l[4]) > 0.2, "ext-mem share at best rung {}", share(&l[4]));
    }

    /// The best rung pins the cluster at the all-capable point: the whole
    /// frame schedules with a single relock (the SW-mode classifier head).
    #[test]
    fn best_rung_is_essentially_relock_free() {
        let cfg = ExecConfig::ladder().last().unwrap().cfg;
        let r = Scheduler::run(&frame_graph(cfg));
        assert!(r.mode_switches <= 1, "{} relocks at the CRY-CNN-SW point", r.mode_switches);
        assert!(r.coresidency_s > 0.0, "tiles must co-reside");
    }

    /// Region-level layer boundaries: a tile's FRAM input fetch waits only
    /// on the producer tiles covering its halo-dilated rows, never on the
    /// whole previous layer (the pre-region barrier).
    #[test]
    fn region_deps_replace_cross_layer_barrier() {
        use crate::soc::sched::Engine;
        let cfg = ExecConfig::ladder().last().unwrap().cfg;
        let g = frame_graph(cfg);
        let is_fram_store = |id: usize| g.jobs[id].engines == [Engine::UdmaFram];
        let (mut n_fetches, mut max_producers, mut min_producers) = (0usize, 0usize, usize::MAX);
        for job in &g.jobs {
            // an input fetch: a FRAM transfer gated on producer FRAM stores
            if job.engines == [Engine::UdmaFram]
                && !job.deps.is_empty()
                && job.deps.iter().all(|&d| is_fram_store(d))
            {
                n_fetches += 1;
                max_producers = max_producers.max(job.deps.len());
                min_producers = min_producers.min(job.deps.len());
            }
        }
        assert!(n_fetches > 10, "expected per-tile input fetches, found {n_fetches}");
        // Pinned (satellite): with TCDM-sized row-band tiles every FRAM
        // fetch waits on at most 5 producer stores — the PR 4 bound, now
        // asserted exactly so a matching regression (toward a barrier, or
        // a mis-tiled 2-D grid widening the fan-in) fails loudly.
        assert!(
            max_producers <= 5,
            "a fetch waits on {max_producers} producers — region matching regressed"
        );
        assert!(min_producers <= 3, "even edge tiles wait on {min_producers} producers");
    }

    /// Tile-granular emission keeps the FRAM round trip off the critical
    /// path: it must beat the layer-granular schedule soundly.
    #[test]
    fn tiled_beats_layer_granular() {
        let best = ExecConfig::ladder().last().unwrap().cfg;
        let tiled = Scheduler::run(&frame_graph(best)).makespan_s;
        let layer =
            Scheduler::run(&frame_graph(ExecConfig { tiling: Tiling::Layer, ..best })).makespan_s;
        assert!(tiled < 0.95 * layer, "tiled {tiled} vs layer-granular {layer}");
    }

    // The scheduled-vs-analytic 5 % calibration and the streaming
    // speedup/never-slower contracts are asserted centrally, across all
    // use cases and rungs, in rust/tests/scheduler.rs.
}
