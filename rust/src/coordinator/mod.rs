//! The application coordinator: expresses the secure-analytics pipelines of
//! §IV as *job graphs* over the simulated SoC's engines (per-core OR10N
//! complex, HWCE, HWCRYPT, cluster DMA, uDMA channels to the external
//! memories and the ADC) and runs them on the event-driven scheduler
//! ([`crate::soc::sched`]).
//!
//! Each use case emits a [`JobGraph`] via the [`GraphBuilder`], whose phase
//! methods carry the calibrated service-time models (§III measurements) and
//! per-component energy charges; the paper's execution discipline (§II-D)
//! then *emerges from the schedule* instead of being hand-approximated:
//!
//! * layers are emitted at **tile granularity** ([`GraphBuilder::push_tiled`]),
//!   sized so a double-buffered tile fits the 64 kB TCDM ([`TCDM_BYTES`]) —
//!   the L2↔TCDM DMA round trips of a layer pipeline *within* the layer;
//! * layer boundaries carry **region-level dependencies**: each tile
//!   records its output [`Extent`], and the next layer's tiles depend only
//!   on the producer tiles covering their (halo-dilated) input region
//!   ([`RegionDeps`]) — layer *i+1* starts fetching while layer *i* is
//!   still storing its last tiles; a gate whose consumers genuinely need
//!   every producer falls back to the barrier;
//! * accelerator phases carry a short control stub on a named core
//!   (`Core(0)` programs the HWCE, `Core(1)` the HWCRYPT), so accelerator
//!   control and SW epilogues co-reside on the core complex while the
//!   engines run autonomously (the cores clock-gate on the event unit);
//! * software epilogues are emitted on the individual cluster cores at the
//!   builder's **cluster point** — the operating mode the workload keeps
//!   the cluster at (the all-capable CRY-CNN-SW point when HWCE and
//!   HWCRYPT phases interleave, §II-D) — so conv, cipher and epilogue
//!   phases co-reside instead of serializing on a mode lock;
//! * I/O and external memories are served by per-interface uDMA channels
//!   that prefetch as early as their data dependencies allow;
//! * operating-point changes that do occur cost the 10 µs FLL relock
//!   (§II-A), counted by the scheduler on genuine frequency changes.
//!
//! Each use case produces a [`UseCaseResult`] with the same breakdown
//! categories as Fig. 10/11/12 and the paper's pJ-per-equivalent-RISC-op
//! metric (OpenRISC-1200-normalized op counts; footnote 4), plus a
//! [`StreamResult`] for the multi-frame streaming mode (`fulmine stream`)
//! that pipelines successive frames through the same graph.
//!
//! The pre-scheduler analytic model (phase times summed on the cluster
//! critical path, I/O hidden up to an overlap backlog) survives as
//! [`JobGraph::analytic`]; `rust/tests/scheduler.rs` pins the scheduled
//! energy to it within 5 % and requires the tiled, co-resident schedule to
//! beat its makespan at the accelerated rungs.

pub mod facedet;
pub mod seizure;
pub mod surveillance;

use crate::energy::{Category, EnergyLedger};
use crate::extmem::Device;
use crate::hwce::golden::WeightPrec;
use crate::soc::opmodes::{OperatingMode, OperatingPoint};
use crate::soc::pm::PolicyKind;
use crate::soc::power::Component;
use crate::soc::sched::{
    Engine, Job, JobGraph, JobId, Scheduler, StreamScheduler, DEFAULT_STREAM_WINDOW, N_CORES,
};

/// TCDM capacity (§II: 64 kB shared L1).
pub const TCDM_BYTES: usize = 64 * 1024;

/// Working-set budget of one tile: half the TCDM, so tiles double-buffer
/// (the DMA fills one half while compute consumes the other).
pub const TILE_BYTES: usize = TCDM_BYTES / 2;

/// Cycles a core spends programming an accelerator job (register writes +
/// trigger; the core then clock-gates on the event unit while the engine
/// runs). Same order as the HWCRYPT's measured
/// [`crate::hwcrypt::JOB_CONFIG_CYCLES`].
pub const ACCEL_CTRL_CYCLES: f64 = 32.0;

/// Granularity at which a use case's layers are emitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Tiling {
    /// One job per layer phase (the pre-tiling emission; kept as the
    /// baseline the tiled schedule is asserted to beat).
    Layer,
    /// Tiles sized to the double-buffered TCDM ([`TILE_BYTES`]).
    Tcdm,
}

/// Exact integer split of `total` into `n` near-equal shares (share `t` of
/// `0..n`); the shares always sum to `total`.
pub fn share(total: usize, n: usize, t: usize) -> usize {
    debug_assert!(t < n);
    total * (t + 1) / n - total * t / n
}

/// [`share`] for 64-bit op counts.
pub fn share64(total: u64, n: u64, t: u64) -> u64 {
    debug_assert!(t < n);
    total * (t + 1) / n - total * t / n
}

/// One labeled rung of a workload's configuration ladder (Fig. 10/11/12):
/// the typed replacement for the former `(&'static str, ExecConfig)` tuples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rung {
    pub label: &'static str,
    pub cfg: ExecConfig,
}

/// Optional per-run overrides on top of a selected [`Rung`]'s
/// [`ExecConfig`] — how a [`crate::system::RunSpec`] expresses ablations
/// (swap the HWCE precision, drop the HWCRYPT, raise VDD, force
/// layer-granular emission) without inventing new rungs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModeOverrides {
    pub n_cores: Option<usize>,
    pub simd_sw: Option<bool>,
    pub hwcrypt: Option<bool>,
    /// `Some(None)` forces software convolution; `Some(Some(prec))` forces
    /// the HWCE at that precision.
    pub hwce: Option<Option<WeightPrec>>,
    pub vdd: Option<f64>,
    pub tiling: Option<Tiling>,
}

impl ModeOverrides {
    pub fn apply(&self, cfg: ExecConfig) -> ExecConfig {
        ExecConfig {
            n_cores: self.n_cores.unwrap_or(cfg.n_cores),
            simd_sw: self.simd_sw.unwrap_or(cfg.simd_sw),
            hwcrypt: self.hwcrypt.unwrap_or(cfg.hwcrypt),
            hwce: self.hwce.unwrap_or(cfg.hwce),
            vdd: self.vdd.unwrap_or(cfg.vdd),
            tiling: self.tiling.unwrap_or(cfg.tiling),
        }
    }
}

/// Execution configuration — one rung of the Fig. 10/11/12 ladder.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecConfig {
    /// Active cores for software kernels.
    pub n_cores: usize,
    /// Use the SIMD-optimized software kernels.
    pub simd_sw: bool,
    /// Offload encryption to the HWCRYPT.
    pub hwcrypt: bool,
    /// Offload convolutions to the HWCE at this precision.
    pub hwce: Option<WeightPrec>,
    /// Cluster supply voltage.
    pub vdd: f64,
    /// Emission granularity (TCDM-sized tiles by default).
    pub tiling: Tiling,
}

impl ExecConfig {
    pub fn sw_1core() -> Self {
        ExecConfig {
            n_cores: 1,
            simd_sw: false,
            hwcrypt: false,
            hwce: None,
            vdd: 0.8,
            tiling: Tiling::Tcdm,
        }
    }
    pub fn sw_4core_simd() -> Self {
        ExecConfig { n_cores: 4, simd_sw: true, ..Self::sw_1core() }
    }
    pub fn with_hwcrypt() -> Self {
        ExecConfig { hwcrypt: true, ..Self::sw_4core_simd() }
    }
    pub fn with_hwce(prec: WeightPrec) -> Self {
        ExecConfig { hwce: Some(prec), ..Self::with_hwcrypt() }
    }

    /// The Fig. 10-style ladder.
    pub fn ladder() -> Vec<Rung> {
        vec![
            Rung { label: "SW 1-core", cfg: Self::sw_1core() },
            Rung { label: "SW 4-core+SIMD", cfg: Self::sw_4core_simd() },
            Rung { label: "+HWCRYPT", cfg: Self::with_hwcrypt() },
            Rung { label: "+HWCE 16b", cfg: Self::with_hwce(WeightPrec::W16) },
            Rung { label: "+HWCE 4b", cfg: Self::with_hwce(WeightPrec::W4) },
        ]
    }

    /// Natural operating mode of convolution phases (the fastest point
    /// whose engine set covers them); a workload may raise the builder's
    /// cluster point above this for co-residency.
    pub fn conv_op(&self) -> OperatingPoint {
        let mode = if self.hwce.is_some() { OperatingMode::KecCnnSw } else { OperatingMode::Sw };
        OperatingPoint::new(mode, self.vdd)
    }

    /// Operating point for encryption phases.
    pub fn crypto_op(&self) -> OperatingPoint {
        let mode = if self.hwcrypt { OperatingMode::CryCnnSw } else { OperatingMode::Sw };
        OperatingPoint::new(mode, self.vdd)
    }

    /// Operating point for software phases.
    pub fn sw_op(&self) -> OperatingPoint {
        OperatingPoint::new(OperatingMode::Sw, self.vdd)
    }
}

/// Software convolution cost constants (cycles per MAC), measured on the VM
/// (see `kernels_sw::conv` tests; asserted against the VM in integration
/// tests): naive ≈ 94 cyc/px ÷ 25 MACs for 5×5, and the 3×3 equivalents.
pub const NAIVE_CYC_PER_MAC_5: f64 = 94.0 / 25.0;
pub const NAIVE_CYC_PER_MAC_3: f64 = 4.4;
/// SIMD 4-core: ≈13 cyc/px ÷ 25 (5×5); 3×3 has worse load/MAC ratio.
pub const SIMD4_CYC_PER_MAC_5: f64 = 13.0 / 25.0;
pub const SIMD4_CYC_PER_MAC_3: f64 = 0.65;

/// OpenRISC-1200 normalization factor: the OR1200 baseline lacks hardware
/// loops and post-increment addressing, costing ≈15 % more instructions for
/// the same kernels (§II ISA-extension discussion).
pub const OR1200_FACTOR: f64 = 1.15;

fn sw_conv_cyc_per_mac(k: usize, cfg: &ExecConfig) -> f64 {
    let (naive, simd4) = if k == 5 {
        (NAIVE_CYC_PER_MAC_5, SIMD4_CYC_PER_MAC_5)
    } else {
        (NAIVE_CYC_PER_MAC_3, SIMD4_CYC_PER_MAC_3)
    };
    if cfg.simd_sw && cfg.n_cores == 4 {
        simd4
    } else if cfg.n_cores == 1 {
        naive
    } else {
        naive / cfg.n_cores as f64 * 1.05 // near-ideal scaling + contention
    }
}

/// Result of one use-case run at one configuration.
#[derive(Debug, Clone)]
pub struct UseCaseResult {
    pub label: String,
    pub time_s: f64,
    pub energy_mj: f64,
    /// OpenRISC-1200-equivalent operations of the workload (config-invariant).
    pub eq_ops: u64,
    pub pj_per_op: f64,
    pub ledger: EnergyLedger,
}

impl UseCaseResult {
    pub fn from_ledger(label: &str, ledger: EnergyLedger, eq_ops: u64) -> Self {
        let energy_mj = ledger.total_mj();
        UseCaseResult {
            label: label.to_string(),
            time_s: ledger.elapsed_s,
            energy_mj,
            eq_ops,
            pj_per_op: energy_mj * 1e9 / eq_ops as f64,
            ledger,
        }
    }
}

/// Result of streaming `frames` successive frames through a use-case graph.
#[derive(Debug, Clone)]
pub struct StreamResult {
    pub label: String,
    pub frames: usize,
    /// Makespan of the streamed schedule (s).
    pub time_s: f64,
    /// Throughput, frames per second.
    pub fps: f64,
    /// Total energy over all frames (mJ).
    pub energy_mj: f64,
    /// Energy per equivalent RISC op, over all frames.
    pub pj_per_op: f64,
    /// Makespan of a single scheduled frame (s).
    pub single_frame_s: f64,
    /// Makespan of the analytic (serialized-cluster) replay of a single
    /// frame — the calibration reference the scheduled frame is measured
    /// against.
    pub single_frame_analytic_s: f64,
    /// Throughput gain over `frames` back-to-back single-frame runs.
    pub speedup: f64,
    pub mode_switches: u64,
    /// Per-engine as-run busy time of the streamed schedule (s), indexed
    /// by [`Engine::index`].
    pub busy_s: [f64; crate::soc::sched::N_ENGINES],
    /// Time with ≥ 2 jobs in flight in the streamed schedule (s).
    pub overlap_s: f64,
    /// Time with ≥ 2 *cluster* jobs in flight (CRY–CNN–SW co-residency).
    pub coresidency_s: f64,
    /// In-flight frame window of the bounded-memory streaming path,
    /// clamped to the stream length (a window wider than the stream could
    /// never fill).
    pub window: usize,
    /// Peak jobs resident in the scheduler at once — bounded by
    /// `window × frame jobs`, independent of the stream length.
    pub peak_resident_jobs: usize,
    /// Jobs scheduled over the whole stream (`frames × frame jobs`).
    pub total_jobs: usize,
    /// Frames executed by the scheduler's steady-state replay instead of
    /// live dispatch — a simulator-performance statistic; replayed frames
    /// are bitwise identical to live execution.
    pub fast_forwarded_frames: usize,
    /// Sleep/DVFS policy managing idle spans (`None` = unmanaged).
    pub policy: Option<PolicyKind>,
    /// Simulated time in policy-managed idle spans (s) — 0 unmanaged.
    pub sleep_s: f64,
    /// Portion of [`StreamResult::sleep_s`] in the deep-sleep rung.
    pub deep_sleep_s: f64,
    /// Wake-up transitions the policy charged.
    pub wake_transitions: u64,
    /// Frames whose output was lost to a fault (sensor dropouts, degraded
    /// frames, exhausted retries) — 0 without a fault model.
    pub frames_dropped: u64,
    /// Retry executions beyond faulted frames' first attempts.
    pub fault_retries: u64,
    /// Full-chip resets (brown-outs plus watchdog resets).
    pub chip_resets: u64,
    /// Frames whose in-flight state a chip reset flushed.
    pub state_loss_frames: u64,
    /// Energy overhead of fault recovery (mJ): re-executed active energy
    /// plus brown-out wake transitions.
    pub recovery_energy_mj: f64,
    pub ledger: EnergyLedger,
}

impl StreamResult {
    /// Fraction of frames whose output survived (1.0 fault-free).
    pub fn availability(&self) -> f64 {
        if self.frames == 0 {
            return 1.0;
        }
        (self.frames as f64 - self.frames_dropped as f64) / self.frames as f64
    }
}

/// Run `graph` single-frame and `frames`-deep (through the bounded-window
/// [`StreamScheduler`] at [`DEFAULT_STREAM_WINDOW`]) and package the
/// comparison.
pub fn stream_graph(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    eq_ops_per_frame: u64,
) -> StreamResult {
    stream_graph_windowed(label, graph, frames, DEFAULT_STREAM_WINDOW, eq_ops_per_frame)
}

/// [`stream_graph`] with an explicit in-flight frame window. Memory and
/// dispatch cost are O(window × frame jobs) however long the stream is;
/// with `window ≥ frames` the schedule is bitwise identical to the
/// materialized `Scheduler::run(&graph.repeat(frames))`.
pub fn stream_graph_windowed(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
) -> StreamResult {
    stream_graph_traffic(label, graph, frames, window, eq_ops_per_frame, &[])
}

/// [`stream_graph_windowed`] under a traffic model: `release[f]` gates
/// frame `f`'s start ([`StreamScheduler::run_traffic`]); an empty slice is
/// the back-to-back path, bit for bit.
pub fn stream_graph_traffic(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
    release: &[f64],
) -> StreamResult {
    stream_graph_traffic_pm(label, graph, frames, window, eq_ops_per_frame, release, None)
}

/// [`stream_graph_traffic`] with idle spans managed by a sleep/DVFS
/// policy ([`crate::soc::pm`]): accounting-only — the schedule is
/// bitwise the unmanaged one; idle-span energy and the sleep statistics
/// change.
pub fn stream_graph_traffic_pm(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
    release: &[f64],
    policy: Option<PolicyKind>,
) -> StreamResult {
    stream_graph_faulted_pm(label, graph, frames, window, eq_ops_per_frame, release, policy, None)
}

/// [`stream_graph_traffic_pm`] under a fault-injection plan
/// ([`crate::fault::FaultPlan`]): faulted frames execute their recovery
/// variants through the scheduler's per-frame variant path, and the
/// plan's reliability counters (plus the brown-out wake energy) attach
/// to the packaged result. `None` routes through the *original*
/// fault-free entry point — bitwise identical to a build without this
/// module (the ISSUE 9 property).
#[allow(clippy::too_many_arguments)]
pub fn stream_graph_faulted_pm(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
    release: &[f64],
    policy: Option<PolicyKind>,
    plan: Option<&crate::fault::FaultPlan>,
) -> StreamResult {
    stream_graph_planned_pm(
        label,
        graph,
        frames,
        window,
        eq_ops_per_frame,
        release,
        policy,
        plan.map(|p| p.variant_refs()),
        |res| {
            if let Some(p) = plan {
                crate::fault::apply_stats(res, &p.stats, 1.0);
            }
        },
    )
}

/// [`stream_graph_traffic_pm`] under a secure-link session plan
/// ([`crate::session::SessionPlan`]): handshake, retransmission and
/// outage frames execute their variants through the scheduler's
/// per-frame variant path, and the plan's session counters attach to
/// the packaged result. `None` routes through the original entry
/// point, bitwise.
#[allow(clippy::too_many_arguments)]
pub fn stream_graph_session_pm(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
    release: &[f64],
    policy: Option<PolicyKind>,
    plan: Option<&crate::session::SessionPlan>,
) -> StreamResult {
    stream_graph_planned_pm(
        label,
        graph,
        frames,
        window,
        eq_ops_per_frame,
        release,
        policy,
        plan.map(|p| p.variant_refs()),
        |res| {
            if let Some(p) = plan {
                crate::session::apply_stats(res, &p.stats, 1.0);
            }
        },
    )
}

/// The shared planned-stream core: run with per-frame variants when a
/// plan supplies them (the [`StreamScheduler`]'s PR 5/PR 9 path —
/// fast-forward suspends around variant frames and re-engages on the
/// steady phase), let `attach` pin the plan's counters onto the raw
/// result, then package the [`StreamResult`].
#[allow(clippy::too_many_arguments)]
fn stream_graph_planned_pm(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    window: usize,
    eq_ops_per_frame: u64,
    release: &[f64],
    policy: Option<PolicyKind>,
    variants: Option<Vec<(usize, &JobGraph)>>,
    attach: impl FnOnce(&mut crate::soc::sched::SchedResult),
) -> StreamResult {
    assert!(frames >= 1, "streaming needs at least one frame");
    // A window wider than the stream clamps to it: the rolling window
    // could never fill the extra slots, and the report should say what
    // actually bounded the run.
    let window = window.min(frames);
    let single = Scheduler::run(graph);
    let analytic = graph.analytic();
    let mut res = match variants {
        None => StreamScheduler::run_compiled_traffic_pm(
            &crate::soc::sched::CompiledFrame::compile(graph),
            frames,
            window,
            release,
            policy,
        ),
        Some(v) => {
            StreamScheduler::run_with_variants_traffic_pm(graph, frames, window, &v, release, policy)
        }
    };
    attach(&mut res);
    let energy_mj = res.ledger.total_mj();
    StreamResult {
        label: label.to_string(),
        frames,
        time_s: res.makespan_s,
        fps: frames as f64 / res.makespan_s,
        energy_mj,
        pj_per_op: energy_mj * 1e9 / (eq_ops_per_frame as f64 * frames as f64),
        single_frame_s: single.makespan_s,
        single_frame_analytic_s: analytic.makespan_s,
        speedup: single.makespan_s * frames as f64 / res.makespan_s,
        mode_switches: res.mode_switches,
        busy_s: res.busy_s,
        overlap_s: res.overlap_s,
        coresidency_s: res.coresidency_s,
        window,
        peak_resident_jobs: res.peak_resident_jobs,
        total_jobs: res.n_jobs,
        fast_forwarded_frames: res.fast_forwarded_frames,
        policy,
        sleep_s: res.sleep_s,
        deep_sleep_s: res.deep_sleep_s,
        wake_transitions: res.wake_transitions,
        frames_dropped: res.frames_dropped,
        fault_retries: res.fault_retries,
        chip_resets: res.chip_resets,
        state_loss_frames: res.state_loss_frames,
        recovery_energy_mj: res.recovery_energy_mj,
        ledger: res.ledger,
    }
}

/// Normalized half-open extent of a tile's data within its layer's
/// spatial range: a row×column *rectangle* `[lo, hi) × [col_lo, col_hi)`
/// in fractional coordinates. The historical 1-D row-band model survives
/// as the fallback — [`Extent::tile`] spans the full column range, so
/// band extents compare, dilate and overlap exactly as before — while
/// [`Extent::grid`] describes a cell of an `nr × nc` tile grid for
/// workloads whose layers tile in both dimensions. A consumer dilates its
/// input extent by the convolution halo (both dimensions; a full-width
/// band clamps to the layer, so the 1-D path is unchanged) before
/// matching producer extents.
///
/// With TCDM-sized tiles the §IV-A layers split into only 6–13 row bands
/// (often a prime count), where a 2-D grid would *widen* the average
/// halo fan-in rather than sharpen it — so the surveillance emitter keeps
/// the band fallback, and the grid path is exercised (and its sharper
/// matching pinned) by the region tests below with larger synthetic
/// grids.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Extent {
    /// Row range (fraction of the layer's rows).
    pub lo: f64,
    pub hi: f64,
    /// Column range (fraction of the layer's columns); `[0, 1)` ≡ the
    /// full-width 1-D band.
    pub col_lo: f64,
    pub col_hi: f64,
}

impl Extent {
    /// The full-width row band of tile `t` of `n` equal shares (the 1-D
    /// fallback; matches how [`share`] splits layer working sets
    /// contiguously).
    pub fn tile(t: usize, n: usize) -> Extent {
        debug_assert!(t < n);
        Extent {
            lo: t as f64 / n as f64,
            hi: (t + 1) as f64 / n as f64,
            col_lo: 0.0,
            col_hi: 1.0,
        }
    }

    /// Cell `(tr, tc)` of an `nr × nc` tile grid — rows split `nr` ways,
    /// columns `nc` ways.
    pub fn grid(tr: usize, nr: usize, tc: usize, nc: usize) -> Extent {
        debug_assert!(tr < nr && tc < nc);
        Extent {
            lo: tr as f64 / nr as f64,
            hi: (tr + 1) as f64 / nr as f64,
            col_lo: tc as f64 / nc as f64,
            col_hi: (tc + 1) as f64 / nc as f64,
        }
    }

    /// Grow all four edges by `halo` (clamped to `[0, 1]`) — the rows and
    /// columns a convolution window reads beyond its output rectangle. A
    /// full-width band clamps to the layer in the column dimension, so
    /// dilation on 1-D extents behaves exactly as the row-only model did.
    pub fn dilate(self, halo: f64) -> Extent {
        self.dilate2(halo, halo)
    }

    /// [`Extent::dilate`] with independent row/column halos (a `k×1`
    /// separable stage reads extra rows but no extra columns).
    pub fn dilate2(self, row_halo: f64, col_halo: f64) -> Extent {
        Extent {
            lo: (self.lo - row_halo).max(0.0),
            hi: (self.hi + row_halo).min(1.0),
            col_lo: (self.col_lo - col_halo).max(0.0),
            col_hi: (self.col_hi + col_halo).min(1.0),
        }
    }

    /// Half-open rectangle overlap (adjacent tiles do not overlap).
    pub fn overlaps(self, other: Extent) -> bool {
        self.lo < other.hi
            && other.lo < self.hi
            && self.col_lo < other.col_hi
            && other.col_lo < self.col_hi
    }
}

/// The producer side of a layer boundary: per-tile job ids, with the
/// output [`Extent`] of each tile when known. A consumer tile depends only
/// on the producers covering its (halo-dilated) input extent — the
/// region-level matching that lets layer *i+1*'s first tiles start while
/// layer *i*'s last tiles are still storing. When extents are unknown
/// (e.g. a gate whose consumers need *every* producer, like the face-
/// detection candidate selection) the matching falls back to the
/// conservative barrier: every consumer depends on every producer.
#[derive(Debug, Clone, Default)]
pub struct RegionDeps {
    jobs: Vec<JobId>,
    /// One extent per job when region information exists; `None` = barrier.
    extents: Option<Vec<Extent>>,
}

impl RegionDeps {
    /// No producers (e.g. the first layer of a frame).
    pub fn none() -> Self {
        RegionDeps::default()
    }

    /// Producers with unknown regions: every consumer waits for all of
    /// them (the conservative cross-layer barrier).
    pub fn barrier(jobs: Vec<JobId>) -> Self {
        RegionDeps { jobs, extents: None }
    }

    /// Producers with known per-tile output extents.
    pub fn tiled(pairs: Vec<(JobId, Extent)>) -> Self {
        let (jobs, extents) = pairs.into_iter().unzip();
        RegionDeps { jobs, extents: Some(extents) }
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// The producer jobs a consumer reading `input` must wait for: the
    /// tiles whose extents overlap it, or all of them under the barrier
    /// fallback.
    pub fn covering(&self, input: Extent) -> Vec<JobId> {
        match &self.extents {
            None => self.jobs.clone(),
            Some(extents) => self
                .jobs
                .iter()
                .zip(extents)
                .filter(|(_, e)| e.overlaps(input))
                .map(|(&j, _)| j)
                .collect(),
        }
    }
}

/// Specification of one tiled convolutional layer for
/// [`GraphBuilder::push_tiled`]: the whole-layer totals, split across
/// tiles by the builder.
#[derive(Debug, Clone, Copy)]
pub struct TiledConv {
    /// Multiply-accumulates of the whole layer.
    pub macs: u64,
    /// Filter size.
    pub k: usize,
    /// Bytes staged L2→TCDM ahead of each tile's convolution (inputs +
    /// weight slice).
    pub stage_in_bytes: usize,
    /// Bytes staged TCDM→L2 after each tile's epilogue (0 = results are
    /// consumed in place or staged by the caller).
    pub stage_out_bytes: usize,
    /// Single-core cycles of the whole layer's software epilogue
    /// (bias/ReLU/pool, dense heads…); 0 = no epilogue.
    pub epi_cycles_1core: f64,
}

/// Job ids emitted by [`GraphBuilder::push_tiled`], one entry per tile.
#[derive(Debug, Clone, Default)]
pub struct TiledConvIds {
    pub stage_in: Vec<JobId>,
    pub convs: Vec<JobId>,
    /// Empty when the spec had no epilogue.
    pub epis: Vec<JobId>,
    /// Empty when the spec had no out-staging.
    pub stage_out: Vec<JobId>,
    /// Output extent of each tile within the layer's spatial range — what
    /// downstream layers match their input regions against.
    pub out_extents: Vec<Extent>,
}

impl TiledConvIds {
    /// The final compute job of tile `t` (its epilogue when present, the
    /// convolution otherwise) — what per-tile consumers depend on.
    pub fn tail(&self, t: usize) -> JobId {
        self.epis.get(t).copied().unwrap_or(self.convs[t])
    }

    /// Final compute jobs of every tile.
    pub fn tails(&self) -> Vec<JobId> {
        (0..self.convs.len()).map(|t| self.tail(t)).collect()
    }

    /// The per-tile tails paired with their output extents, as a
    /// [`RegionDeps`] producer set for the next layer.
    pub fn tail_regions(&self) -> RegionDeps {
        RegionDeps::tiled(
            (0..self.convs.len()).map(|t| (self.tail(t), self.out_extents[t])).collect(),
        )
    }
}

/// Builds a [`JobGraph`] phase by phase. Each method mirrors one phase kind
/// of the paper's pipelines, computing its engines, service time (from the
/// §III-calibrated cycle models) and energy charges from the [`ExecConfig`];
/// dependencies are explicit job ids returned by earlier calls.
pub struct GraphBuilder {
    pub cfg: ExecConfig,
    graph: JobGraph,
    /// Mode of the most recently emitted cluster job — DMA transfers run on
    /// the cluster clock, so their service time and charge follow it (the
    /// same convention the analytic model used).
    emission_mode: Option<OperatingMode>,
    /// The operating mode the workload keeps the cluster at for its
    /// convolution and epilogue phases — see [`GraphBuilder::set_cluster_point`].
    cluster_point: OperatingMode,
    /// Which crypto cost model prices the `xts`/`sponge_ae` phases —
    /// defaults to the configuration's native backend (HWCRYPT when the
    /// rung has it, software otherwise), overridden for the CryptoSRAM-
    /// style backend ablation ([`crate::session::BackendKind`]).
    backend: crate::session::BackendKind,
}

impl GraphBuilder {
    pub fn new(cfg: ExecConfig) -> Self {
        // Natural point ([`ExecConfig::conv_op`]): the fastest mode that
        // covers the convolution engine; workloads with interleaved
        // HWCRYPT traffic raise it to the all-capable CRY-CNN-SW point
        // for co-residency.
        let cluster_point = cfg.conv_op().mode;
        let backend = crate::session::BackendKind::native(&cfg);
        GraphBuilder { cfg, graph: JobGraph::new(), emission_mode: None, cluster_point, backend }
    }

    /// Override the crypto cost model for every subsequent `xts` and
    /// `sponge_ae` phase. The default ([`crate::session::BackendKind::native`])
    /// reproduces the configuration's own arms bitwise.
    pub fn set_backend(&mut self, backend: crate::session::BackendKind) {
        self.backend = backend;
    }

    /// The active crypto backend.
    pub fn backend(&self) -> crate::session::BackendKind {
        self.backend
    }

    /// Pin the cluster at `mode` for convolution and epilogue phases. A
    /// workload whose steady state interleaves HWCE and HWCRYPT work (e.g.
    /// §IV-A, which decrypts and re-encrypts every tile) sets the
    /// all-capable [`OperatingMode::CryCnnSw`] point here: everything then
    /// shares one clock and co-resides with zero relocks, trading the
    /// KEC-mode frequency margin for full overlap (§II-D). Panics if the
    /// point cannot host the configured convolution engine.
    pub fn set_cluster_point(&mut self, mode: OperatingMode) {
        if self.cfg.hwce.is_some() {
            assert!(mode.hwce_available(), "cluster point {mode:?} cannot host the HWCE");
        }
        self.cluster_point = mode;
    }

    /// The current cluster point (conv/epilogue emission mode).
    pub fn cluster_point(&self) -> OperatingMode {
        self.cluster_point
    }

    /// Detach the external flash/FRAM (no standby charge) — §IV-C.
    pub fn set_ext_mem_present(&mut self, present: bool) {
        self.graph.ext_mem_present = present;
    }

    /// Whether the external memories are currently attached.
    pub fn ext_mem_present(&self) -> bool {
        self.graph.ext_mem_present
    }

    /// Jobs emitted so far.
    pub fn len(&self) -> usize {
        self.graph.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graph.is_empty()
    }

    /// Open a named segment (e.g. one tenant of a mixed multi-tenant
    /// workload) — see [`JobGraph::mark_segment`].
    pub fn begin_segment(&mut self, label: &str) {
        self.graph.mark_segment(label);
    }

    pub fn build(self) -> JobGraph {
        self.graph
    }

    /// Tiles a working set of `bytes` splits into so each tile fits the
    /// double-buffered TCDM half ([`TILE_BYTES`]); 1 under layer-granular
    /// emission.
    pub fn tiles(&self, working_set_bytes: usize) -> usize {
        match self.cfg.tiling {
            Tiling::Layer => 1,
            Tiling::Tcdm => working_set_bytes.div_ceil(TILE_BYTES).max(1),
        }
    }

    /// The first `n` cluster cores.
    fn core_set(&self, n: usize) -> Vec<Engine> {
        (0..n.min(N_CORES)).map(|i| Engine::Core(i as u8)).collect()
    }

    /// The core that programs the HWCE.
    fn hwce_ctrl_core(&self) -> Engine {
        Engine::Core(0)
    }

    /// The core that programs the HWCRYPT (off the HWCE controller when
    /// the complex has more than one core).
    fn crypto_ctrl_core(&self) -> Engine {
        if self.cfg.n_cores > 1 {
            Engine::Core(1)
        } else {
            Engine::Core(0)
        }
    }

    /// Operating point for SOC-side movers: the cluster clock at the mode
    /// of the last cluster phase.
    fn mover_op(&self) -> OperatingPoint {
        OperatingPoint::new(self.emission_mode.unwrap_or(OperatingMode::Sw), self.cfg.vdd)
    }

    fn push(
        &mut self,
        label: &'static str,
        engines: Vec<Engine>,
        op: OperatingPoint,
        duration_s: f64,
        deps: &[JobId],
        charges: Vec<(Category, Component, f64)>,
    ) -> JobId {
        if engines.iter().any(|e| e.mode_locked()) {
            self.emission_mode = Some(op.mode);
        }
        self.graph.push(Job { label, engines, op, duration_s, deps: deps.to_vec(), charges })
    }

    /// A control stub: the named core programs an accelerator job
    /// ([`ACCEL_CTRL_CYCLES`]) and hands it off; the accelerator job
    /// depends on it. Control therefore occupies the core complex only for
    /// the programming interval — the core clock-gates on the event unit
    /// while the engine runs (§II) — which is what lets epilogues
    /// co-reside with accelerator control on the remaining cores. Energy
    /// stays on the accelerator job's controller-core charge (the
    /// calibrated §III anchors include it).
    fn accel_ctrl(&mut self, core: Engine, op: OperatingPoint, deps: &[JobId]) -> JobId {
        self.push("ctrl", vec![core], op, ACCEL_CTRL_CYCLES / op.freq_hz(), deps, Vec::new())
    }

    /// A convolution phase over `macs` MACs with filter size `k` — on the
    /// HWCE (programmed from `Core(0)`, running at the cluster point) or
    /// on the software cores.
    pub fn conv(&mut self, macs: u64, k: usize, deps: &[JobId]) -> JobId {
        match self.cfg.hwce {
            Some(prec) => {
                let op = OperatingPoint::new(self.cluster_point, self.cfg.vdd);
                let cycles = macs as f64 / (k * k) as f64
                    * crate::hwce::timing::analytic_cycles_per_px(k, prec);
                let ctrl = self.accel_ctrl(self.hwce_ctrl_core(), op, deps);
                self.push(
                    "conv",
                    vec![Engine::Hwce],
                    op,
                    cycles / op.freq_hz(),
                    &[ctrl],
                    vec![
                        (Category::Conv, Component::Core, 1.0), // controller core
                        (Category::Conv, Component::ClusterInfra, 1.0),
                        (Category::Conv, Component::Hwce, 1.0),
                    ],
                )
            }
            None => {
                let op = OperatingPoint::new(self.cluster_point, self.cfg.vdd);
                let cycles = macs as f64 * sw_conv_cyc_per_mac(k, &self.cfg);
                let engines = self.core_set(self.cfg.n_cores);
                self.push(
                    "conv",
                    engines,
                    op,
                    cycles / op.freq_hz(),
                    deps,
                    vec![
                        (Category::Conv, Component::Core, self.cfg.n_cores as f64),
                        (Category::Conv, Component::ClusterInfra, 1.0),
                    ],
                )
            }
        }
    }

    /// An AES-128-XTS phase over `bytes` (en- or decryption), priced by
    /// the active [`crate::session::CryptoBackend`] — the HWCRYPT path
    /// needs the all-capable CRY-CNN-SW point and is programmed from the
    /// crypto controller core; the software and in-SRAM models run on
    /// the cores.
    pub fn xts(&mut self, bytes: usize, deps: &[JobId]) -> JobId {
        let cost = self.backend.model().xts(&self.cfg, self.cluster_point, bytes);
        self.emit_crypto("xts", cost, deps)
    }

    /// A sponge authenticated-encryption phase (KEC-CNN-SW capable; the
    /// HWCRYPT backend hosts it at the cluster point when that point
    /// covers the KECCAK datapath), priced by the active backend.
    pub fn sponge_ae(&mut self, bytes: usize, deps: &[JobId]) -> JobId {
        let cost = self.backend.model().sponge_ae(&self.cfg, self.cluster_point, bytes);
        self.emit_crypto("sponge-ae", cost, deps)
    }

    /// Lower one priced crypto phase: accelerator-backed costs get a
    /// control stub from the crypto controller core and the engine job;
    /// core-backed costs occupy their core set directly.
    fn emit_crypto(&mut self, label: &'static str, cost: crate::session::CryptoCost, deps: &[JobId]) -> JobId {
        let op = cost.op(&self.cfg);
        match cost.accel {
            Some(engine) => {
                let ctrl = self.accel_ctrl(self.crypto_ctrl_core(), op, deps);
                self.push(label, vec![engine], op, cost.cycles / op.freq_hz(), &[ctrl], cost.charges)
            }
            None => {
                let engines = self.core_set(cost.cores);
                self.push(label, engines, op, cost.cycles / op.freq_hz(), deps, cost.charges)
            }
        }
    }

    /// The secure-link handshake placeholders: a cookie-exchange job and
    /// a flight job on `Core(0)` at the cluster point, both zero-duration
    /// (zero energy) in the steady template. A [`crate::session::SessionPlan`]
    /// inflates them on handshake frames; record jobs that must wait for
    /// session establishment depend on the returned flight id.
    pub fn session_handshake(&mut self) -> (JobId, JobId) {
        let op = OperatingPoint::new(self.cluster_point, self.cfg.vdd);
        let charges = vec![
            (Category::OtherSw, Component::Core, 1.0),
            (Category::OtherSw, Component::ClusterInfra, 1.0),
        ];
        let cookie = self.push(
            crate::session::HS_COOKIE_LABEL,
            vec![Engine::Core(0)],
            op,
            0.0,
            &[],
            charges.clone(),
        );
        let flight = self.push(
            crate::session::HS_FLIGHT_LABEL,
            vec![Engine::Core(0)],
            op,
            0.0,
            &[cookie],
            charges,
        );
        (cookie, flight)
    }

    /// A software phase of `cycles_1core` single-core cycles with a
    /// parallelizable fraction `par` (Amdahl over the config's cores). The
    /// phase owns the configured cores for its whole interval and runs at
    /// the SW point (its own mode window).
    pub fn sw(&mut self, cycles_1core: f64, par: f64, deps: &[JobId]) -> JobId {
        self.sw_split(cycles_1core * (1.0 - par), cycles_1core * par, deps)
    }

    /// A software phase given explicit serial and parallelizable cycle
    /// pools: the serial part runs on one core while the others wait at
    /// the barrier (still clocked, as the lump model charged), the
    /// parallel part splits across the configured cores.
    pub fn sw_split(&mut self, serial_cycles: f64, parallel_cycles: f64, deps: &[JobId]) -> JobId {
        let op = self.cfg.sw_op();
        let n = self.cfg.n_cores as f64;
        let cycles = serial_cycles + parallel_cycles / n;
        let engines = self.core_set(self.cfg.n_cores);
        self.push(
            "sw",
            engines,
            op,
            cycles / op.freq_hz(),
            deps,
            vec![
                (Category::OtherSw, Component::Core, n),
                (Category::OtherSw, Component::ClusterInfra, 1.0),
            ],
        )
    }

    /// A fully-parallel software epilogue of `cycles_1core` single-core
    /// cycles, emitted at the *cluster point* on the individual cores —
    /// so it co-resides with accelerator phases instead of forcing the
    /// cluster through a SW-mode window (total core-cycles, and therefore
    /// active energy, match the equivalent [`GraphBuilder::sw`] phase).
    pub fn epilogue(&mut self, cycles_1core: f64, deps: &[JobId]) -> JobId {
        let op = OperatingPoint::new(self.cluster_point, self.cfg.vdd);
        let engines = self.core_set(self.cfg.n_cores);
        let n = engines.len() as f64;
        self.push(
            "epilogue",
            engines,
            op,
            cycles_1core / n / op.freq_hz(),
            deps,
            vec![
                (Category::OtherSw, Component::Core, n),
                (Category::OtherSw, Component::ClusterInfra, 1.0),
            ],
        )
    }

    /// Emit one convolutional layer at tile granularity: per tile, the
    /// L2→TCDM staging DMA, the convolution (with its control stub), the
    /// software epilogue on the cores and the optional TCDM→L2 staging
    /// back — each tile chained only through its own dependencies, so the
    /// staging of tile *t+1* pipelines under the compute of tile *t*
    /// (double buffering within the layer). `per_tile_deps[t]` supplies
    /// the tile's external inputs (e.g. its decrypted operands); pass `&[]`
    /// when the layer has none. `n_tiles` normally comes from
    /// [`GraphBuilder::tiles`] over the layer's TCDM working set. Each
    /// tile's output [`Extent`] is recorded in the returned ids, so the
    /// next layer can depend only on the producer tiles covering its
    /// input region ([`RegionDeps`]) instead of barriering on the whole
    /// layer.
    pub fn push_tiled(
        &mut self,
        n_tiles: usize,
        spec: &TiledConv,
        per_tile_deps: &[Vec<JobId>],
    ) -> TiledConvIds {
        assert!(n_tiles >= 1, "a layer has at least one tile");
        assert!(
            per_tile_deps.is_empty() || per_tile_deps.len() == n_tiles,
            "per-tile deps must match the tile count ({} vs {n_tiles})",
            per_tile_deps.len()
        );
        let mut ids = TiledConvIds::default();
        for t in 0..n_tiles {
            let deps: &[JobId] = per_tile_deps.get(t).map(Vec::as_slice).unwrap_or(&[]);
            let si = self.dma(share(spec.stage_in_bytes, n_tiles, t), deps);
            let cv = self.conv(share64(spec.macs, n_tiles as u64, t as u64), spec.k, &[si]);
            ids.stage_in.push(si);
            ids.convs.push(cv);
            ids.out_extents.push(Extent::tile(t, n_tiles));
            let mut tail = cv;
            if spec.epi_cycles_1core > 0.0 {
                let ep = self.epilogue(spec.epi_cycles_1core / n_tiles as f64, &[cv]);
                ids.epis.push(ep);
                tail = ep;
            }
            if spec.stage_out_bytes > 0 {
                ids.stage_out.push(self.dma(share(spec.stage_out_bytes, n_tiles, t), &[tail]));
            }
        }
        ids
    }

    /// Cluster-DMA staging of `bytes` L2↔TCDM (8 B/cycle AXI), concurrent
    /// with compute on its own engine.
    pub fn dma(&mut self, bytes: usize, deps: &[JobId]) -> JobId {
        let op = self.mover_op();
        let duration = bytes as f64 / 8.0 / op.freq_hz();
        self.push(
            "dma",
            vec![Engine::ClusterDma],
            op,
            duration,
            deps,
            vec![(Category::Dma, Component::ClusterInfra, 1.0)],
        )
    }

    /// Sensor acquisition over the dedicated ADC uDMA channel (§II: the
    /// uDMA serves its peripherals on independent channels, even with the
    /// cluster asleep) — a burst from the ADC FIFO at the AXI-side width,
    /// concurrent with cluster compute and the other movers.
    pub fn adc(&mut self, bytes: usize, deps: &[JobId]) -> JobId {
        let op = self.mover_op();
        let duration = bytes as f64 / 8.0 / op.freq_hz();
        self.push(
            "adc",
            vec![Engine::UdmaAdc],
            op,
            duration,
            deps,
            vec![(Category::Dma, Component::SocDomain, 1.0)],
        )
    }

    /// External-memory traffic over the device's uDMA channel (flash or
    /// FRAM), concurrent with cluster compute.
    pub fn extmem(&mut self, device: Device, bytes: usize, deps: &[JobId]) -> JobId {
        let (engine, comp) = match device {
            Device::Flash => (Engine::UdmaFlash, Component::Flash),
            Device::Fram => (Engine::UdmaFram, Component::Fram),
        };
        let op = self.mover_op();
        let duration = bytes as f64 / device.bandwidth_bps();
        self.push(
            "extmem",
            vec![engine],
            op,
            duration,
            deps,
            vec![(Category::ExtMem, comp, 1.0), (Category::ExtMem, Component::SocDomain, 1.0)],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Makespan of a single-phase graph built by `f`.
    fn phase_time(cfg: ExecConfig, f: impl FnOnce(&mut GraphBuilder) -> JobId) -> f64 {
        let mut b = GraphBuilder::new(cfg);
        f(&mut b);
        Scheduler::run(&b.build()).makespan_s
    }

    #[test]
    fn ladder_has_five_rungs() {
        let l = ExecConfig::ladder();
        assert_eq!(l.len(), 5);
        assert_eq!(l[0].cfg.n_cores, 1);
        assert!(l[4].cfg.hwce == Some(WeightPrec::W4));
        assert!(l.iter().all(|r| r.cfg.tiling == Tiling::Tcdm));
    }

    #[test]
    fn overrides_apply_field_by_field() {
        let base = ExecConfig::with_hwce(WeightPrec::W4);
        assert_eq!(ModeOverrides::default().apply(base), base);
        let o = ModeOverrides { hwcrypt: Some(false), vdd: Some(1.2), ..Default::default() };
        let cfg = o.apply(base);
        assert!(!cfg.hwcrypt);
        assert_eq!(cfg.vdd, 1.2);
        assert_eq!(cfg.hwce, base.hwce);
        assert_eq!(cfg.n_cores, base.n_cores);
        let sw = ModeOverrides { hwce: Some(None), ..Default::default() }.apply(base);
        assert_eq!(sw.hwce, None);
        let layered = ModeOverrides { tiling: Some(Tiling::Layer), ..Default::default() }.apply(base);
        assert_eq!(layered.tiling, Tiling::Layer);
    }

    #[test]
    fn shares_partition_exactly() {
        for (total, n) in [(0usize, 1usize), (7, 3), (64 * 1024, 5), (1_000_003, 17)] {
            let sum: usize = (0..n).map(|t| share(total, n, t)).sum();
            assert_eq!(sum, total, "{total}/{n}");
        }
        let total = 2_300_000_017u64;
        let sum: u64 = (0..53u64).map(|t| share64(total, 53, t)).sum();
        assert_eq!(sum, total);
    }

    #[test]
    fn tiles_respect_tcdm_and_granularity() {
        let b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        assert_eq!(b.tiles(1), 1);
        assert_eq!(b.tiles(TILE_BYTES), 1);
        assert_eq!(b.tiles(TILE_BYTES + 1), 2);
        assert_eq!(b.tiles(10 * TILE_BYTES), 10);
        let layer = GraphBuilder::new(ExecConfig {
            tiling: Tiling::Layer,
            ..ExecConfig::with_hwce(WeightPrec::W4)
        });
        assert_eq!(layer.tiles(10 * TILE_BYTES), 1);
    }

    #[test]
    fn hwce_conv_much_faster_than_sw() {
        let macs = 100_000_000u64;
        let t_sw = phase_time(ExecConfig::sw_1core(), |b| b.conv(macs, 3, &[]));
        let t_hw = phase_time(ExecConfig::with_hwce(WeightPrec::W16), |b| b.conv(macs, 3, &[]));
        let speedup = t_sw / t_hw;
        // §III-C: 82× vs naive single core (the mode-frequency difference
        // trims it slightly; anything 40–90 is the right shape)
        assert!(speedup > 25.0 && speedup < 100.0, "speedup {speedup}");
    }

    #[test]
    fn hwcrypt_xts_much_faster_than_sw() {
        let bytes = 1 << 20;
        let t_sw = phase_time(ExecConfig::sw_1core(), |b| b.xts(bytes, &[]));
        let t_hw = phase_time(ExecConfig::with_hwcrypt(), |b| b.xts(bytes, &[]));
        let speedup = t_sw / t_hw;
        assert!(speedup > 200.0 && speedup < 600.0, "speedup {speedup}");
    }

    #[test]
    fn mode_switch_counted_and_costed() {
        // conv at the default KEC point, XTS at CRY: two genuine relocks
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c1 = b.conv(1_000_000, 3, &[]); // KEC point
        let x = b.xts(1 << 20, &[c1]); // CRY — switch
        b.conv(1_000_000, 3, &[x]); // back — switch
        let r = Scheduler::run(&b.build());
        assert_eq!(r.mode_switches, 2);
    }

    /// Raising the cluster point to CRY-CNN-SW makes the same chain
    /// relock-free: conv, cipher and epilogue share the all-capable point.
    #[test]
    fn cry_point_removes_relocks() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        b.set_cluster_point(OperatingMode::CryCnnSw);
        let c1 = b.conv(1_000_000, 3, &[]);
        let x = b.xts(1 << 20, &[c1]);
        let c2 = b.conv(1_000_000, 3, &[x]);
        b.epilogue(10_000.0, &[c2]);
        let r = Scheduler::run(&b.build());
        assert_eq!(r.mode_switches, 0, "all phases share the CRY-CNN-SW point");
    }

    #[test]
    fn io_overlaps_compute() {
        let cfg = ExecConfig::with_hwce(WeightPrec::W4);
        // compute-dominated: a prefetchable ext-mem transfer is fully hidden
        let mut a = GraphBuilder::new(cfg);
        a.conv(500_000_000, 3, &[]);
        a.extmem(Device::Fram, 1024, &[]);
        let ta = Scheduler::run(&a.build()).makespan_s;
        let tb = phase_time(cfg, |b| b.conv(500_000_000, 3, &[]));
        assert!((ta - tb).abs() / tb < 0.01);
        // io-dominated: the transfer is the critical path
        let mut c = GraphBuilder::new(cfg);
        c.conv(1_000, 3, &[]);
        c.extmem(Device::Fram, 10 << 20, &[]);
        let tc = Scheduler::run(&c.build()).makespan_s;
        assert!(tc > 0.4, "10 MB at 20 MB/s must take ≥0.5 s");
    }

    #[test]
    fn sw_phase_amdahl() {
        let t1 = phase_time(ExecConfig::sw_1core(), |b| b.sw(1e9, 0.9, &[]));
        let t4 = phase_time(ExecConfig::sw_4core_simd(), |b| b.sw(1e9, 0.9, &[]));
        let s = t1 / t4;
        assert!((s - 1.0 / (0.1 + 0.9 / 4.0)).abs() < 0.05, "amdahl {s}");
    }

    /// An epilogue phase carries the same core-cycles (and therefore
    /// active energy) as the equivalent fully-parallel `sw` phase, but at
    /// the cluster point so it can co-reside with accelerator work.
    #[test]
    fn epilogue_energy_matches_sw_phase() {
        let cycles = 5e6;
        let cfg = ExecConfig::with_hwce(WeightPrec::W4);
        let mut a = GraphBuilder::new(cfg);
        a.epilogue(cycles, &[]);
        let ga = a.build();
        let mut b = GraphBuilder::new(cfg);
        b.sw(cycles, 1.0, &[]);
        let gb = b.build();
        let (ea, eb) = (ga.active_mj(), gb.active_mj());
        // core charges identical; only the ClusterInfra share differs with
        // the point's frequency — a few percent of a small term
        assert!((ea - eb).abs() / eb < 0.05, "epilogue {ea} vs sw {eb}");
    }

    #[test]
    fn push_tiled_emits_pipelined_tiles() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let spec = TiledConv {
            macs: 9_000_000,
            k: 3,
            stage_in_bytes: 3 * TILE_BYTES,
            stage_out_bytes: 3 * TILE_BYTES / 2,
            epi_cycles_1core: 300_000.0,
        };
        let n = b.tiles(spec.stage_in_bytes);
        assert_eq!(n, 3);
        let ids = b.push_tiled(n, &spec, &[]);
        assert_eq!(ids.convs.len(), 3);
        assert_eq!(ids.epis.len(), 3);
        assert_eq!(ids.stage_out.len(), 3);
        assert_eq!(ids.tails(), ids.epis);
        let g = b.build();
        // tiles pipeline: the 3-tile schedule beats 3× a 1-tile-serial
        // schedule's span because DMA/conv/epilogue of adjacent tiles
        // overlap, and never beats the critical path of one tile chain
        let r = Scheduler::run(&g);
        assert!(r.overlap_s > 0.0, "tiles must overlap");
        assert!(r.makespan_s <= g.serialized_bound());
        // every tile's conv depends on its own staging only
        for t in 0..3 {
            assert_eq!(g.jobs[ids.convs[t]].deps.len(), 1, "conv deps via ctrl stub");
        }
    }

    #[test]
    fn energy_breakdown_populated() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c = b.conv(10_000_000, 3, &[]);
        let x = b.xts(100_000, &[c]);
        b.sw(1e6, 1.0, &[x]);
        b.extmem(Device::Flash, 100_000, &[]);
        let l = Scheduler::run(&b.build()).ledger;
        for cat in [Category::Conv, Category::Crypto, Category::OtherSw, Category::ExtMem] {
            assert!(l.energy_mj(cat) > 0.0, "{cat:?} empty");
        }
        assert!(l.total_mj() > 0.0 && l.elapsed_s > 0.0);
    }

    /// The scheduled and analytic models agree exactly on a serial chain
    /// whose I/O fits under compute — the calibration contract.
    #[test]
    fn scheduled_matches_analytic_on_serial_chain() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c = b.conv(50_000_000, 3, &[]);
        let s = b.sw(1e6, 1.0, &[c]);
        let x = b.xts(100_000, &[s]);
        b.dma(64 * 1024, &[x]);
        let g = b.build();
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        assert!((run.makespan_s - ana.makespan_s).abs() / ana.makespan_s < 1e-9);
        assert_eq!(run.mode_switches, ana.mode_switches);
        assert!((run.ledger.total_mj() - ana.ledger.total_mj()).abs() < 1e-9);
    }

    #[test]
    fn stream_result_consistent() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c = b.conv(10_000_000, 3, &[]);
        let x = b.xts(100_000, &[c]);
        b.extmem(Device::Fram, 200_000, &[x]);
        let g = b.build();
        let r = stream_graph("test", &g, 4, 1_000_000);
        assert_eq!(r.frames, 4);
        assert!(r.time_s > 0.0 && r.fps > 0.0);
        assert!((r.fps - 4.0 / r.time_s).abs() < 1e-9);
        assert!(r.speedup >= 0.99, "streaming slower than serial: {}", r.speedup);
        assert!(r.time_s >= r.single_frame_s - 1e-12);
        assert!(r.single_frame_analytic_s > 0.0);
        // the default window clamps to the 4-frame stream
        assert_eq!(r.window, crate::soc::sched::DEFAULT_STREAM_WINDOW.min(r.frames));
        assert!(r.peak_resident_jobs <= r.window * g.len());
        // an explicit window covering the stream matches the default run
        // here (4 frames ≤ the default window ⇒ both are the full graph)
        let rw = stream_graph_windowed("test", &g, 4, 4, 1_000_000);
        assert_eq!(rw.window, 4);
        assert_eq!(rw.time_s.to_bits(), r.time_s.to_bits());
        assert_eq!(rw.energy_mj.to_bits(), r.energy_mj.to_bits());
        // an oversized window reports — and behaves as — the clamped one
        let huge = stream_graph_windowed("test", &g, 4, 4096, 1_000_000);
        assert_eq!(huge.window, 4, "window must clamp to the stream length");
        assert_eq!(huge.time_s.to_bits(), r.time_s.to_bits());
        assert_eq!(huge.peak_resident_jobs, r.peak_resident_jobs);
    }

    #[test]
    fn extents_tile_and_overlap() {
        let a = Extent::tile(0, 4);
        let b = Extent::tile(1, 4);
        let d = Extent::tile(3, 4);
        assert!(!a.overlaps(b), "adjacent half-open tiles do not overlap");
        assert!(a.overlaps(a));
        assert!(!a.overlaps(d));
        // a one-row halo on a 28-row layer reaches into the neighbour tile
        let haloed = b.dilate(1.0 / 28.0);
        assert!(haloed.overlaps(a) && haloed.overlaps(Extent::tile(2, 4)));
        assert!(!haloed.overlaps(d));
        // clamping at the borders
        let edge = Extent::tile(0, 4).dilate(0.5);
        assert_eq!(edge.lo, 0.0);
    }

    #[test]
    fn region_deps_cover_overlapping_producers_only() {
        let tiled = RegionDeps::tiled(vec![
            (10, Extent::tile(0, 3)),
            (11, Extent::tile(1, 3)),
            (12, Extent::tile(2, 3)),
        ]);
        // an un-dilated middle tile maps to its own producer
        assert_eq!(tiled.covering(Extent::tile(1, 3)), vec![11]);
        // a halo reaches the neighbours
        assert_eq!(tiled.covering(Extent::tile(1, 3).dilate(0.01)), vec![10, 11, 12]);
        // different consumer tiling still resolves by overlap
        assert_eq!(tiled.covering(Extent::tile(0, 2)), vec![10, 11]);
        // the barrier fallback hands back every producer
        let barrier = RegionDeps::barrier(vec![10, 11, 12]);
        assert_eq!(barrier.covering(Extent::tile(0, 5)), vec![10, 11, 12]);
        assert!(RegionDeps::none().covering(Extent::tile(0, 1)).is_empty());
        assert!(RegionDeps::none().is_empty() && !tiled.is_empty());
    }

    /// 2-D tile grids (satellite): rectangle extents discriminate columns
    /// where the 1-D band fallback pulls in whole tile rows — the halo
    /// fan-in of a grid consumer is its 3×3 neighbourhood, not 3 rows of
    /// tiles.
    #[test]
    fn grid_extents_sharpen_halo_matching() {
        let (nr, nc) = (6usize, 6usize);
        let cells: Vec<(JobId, Extent)> = (0..nr * nc)
            .map(|i| (i, Extent::grid(i / nc, nr, i % nc, nc)))
            .collect();
        let grid = RegionDeps::tiled(cells);
        let halo = 0.01;
        let consumer = Extent::grid(2, nr, 3, nc).dilate(halo);
        let covered = grid.covering(consumer);
        assert_eq!(covered.len(), 9, "3x3 neighbourhood, got {covered:?}");
        // the same producers described as full-width row bands (the 1-D
        // fallback) cannot discriminate columns: the row halo pulls in
        // three whole tile rows
        let bands = RegionDeps::tiled(
            (0..nr * nc).map(|i| (i, Extent::tile(i / nc, nr))).collect(),
        );
        let banded = bands.covering(Extent::tile(2, nr).dilate(halo));
        assert_eq!(banded.len(), 3 * nc, "bands pull whole tile rows");
        assert!(covered.len() < banded.len(), "grids must sharpen the fan-in");
        // un-dilated cells map 1:1; a separable row-only halo keeps the
        // column fan-in tight
        assert_eq!(grid.covering(Extent::grid(2, nr, 3, nc)).len(), 1);
        assert_eq!(grid.covering(Extent::grid(2, nr, 3, nc).dilate2(halo, 0.0)).len(), 3);
        // grid cells degenerate to bands at nc = 1
        assert_eq!(Extent::grid(2, nr, 0, 1), Extent::tile(2, nr));
    }

    /// Band extents keep their exact pre-rectangle semantics: column range
    /// [0,1), dilation clamps, and band↔band matching is the 1-D interval
    /// test.
    #[test]
    fn band_extents_preserve_1d_semantics() {
        let band = Extent::tile(1, 4);
        assert_eq!((band.col_lo, band.col_hi), (0.0, 1.0));
        let d = band.dilate(0.3);
        assert_eq!((d.col_lo, d.col_hi), (0.0, 1.0), "full-width bands clamp");
        // a band always overlaps any cell in its row range, whatever column
        assert!(band.overlaps(Extent::grid(1, 4, 7, 8)));
        assert!(!band.overlaps(Extent::grid(3, 4, 0, 8)));
    }

    #[test]
    fn push_tiled_records_out_extents() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let spec = TiledConv {
            macs: 9_000_000,
            k: 3,
            stage_in_bytes: 3 * TILE_BYTES,
            stage_out_bytes: 0,
            epi_cycles_1core: 0.0,
        };
        let ids = b.push_tiled(3, &spec, &[]);
        assert_eq!(ids.out_extents.len(), 3);
        assert_eq!(ids.out_extents[0], Extent::tile(0, 3));
        assert_eq!(ids.out_extents[2], Extent::tile(2, 3));
        let regions = ids.tail_regions();
        assert_eq!(regions.covering(Extent::tile(2, 3)), vec![ids.tail(2)]);
    }
}
