//! The application coordinator: composes the simulated SoC's engines (cores,
//! HWCE, HWCRYPT, DMA, uDMA, external memories) into the secure-analytics
//! pipelines of §IV, with the paper's execution discipline (§II-D):
//!
//! * tiles sized to the 64 kB TCDM, staged L2↔TCDM by the cluster DMA with
//!   double buffering (DMA time overlaps compute; only the excess shows on
//!   the critical path);
//! * I/O and external memories served by the uDMA concurrently with cluster
//!   compute (again max(), not sum);
//! * HWCE and HWCRYPT are time-interleaved on the shared accelerator ports,
//!   so their phases *add*;
//! * operating-mode switching (CRY-CNN-SW ↔ KEC-CNN-SW ↔ SW) costs 10 µs
//!   per switch (§II-A fast FLL relock), as exploited by §IV-A.
//!
//! Each use case produces a [`UseCaseResult`] with the same breakdown
//! categories as Fig. 10/11/12 and the paper's pJ-per-equivalent-RISC-op
//! metric (OpenRISC-1200-normalized op counts; footnote 4).

pub mod facedet;
pub mod seizure;
pub mod surveillance;

use crate::energy::{Category, EnergyLedger};
use crate::hwce::golden::WeightPrec;
use crate::hwcrypt;
use crate::kernels_sw::crypto_cost;
use crate::soc::opmodes::{OperatingMode, OperatingPoint, MODE_SWITCH_S};
use crate::soc::power::Component;

/// Execution configuration — one rung of the Fig. 10/11/12 ladder.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecConfig {
    /// Active cores for software kernels.
    pub n_cores: usize,
    /// Use the SIMD-optimized software kernels.
    pub simd_sw: bool,
    /// Offload encryption to the HWCRYPT.
    pub hwcrypt: bool,
    /// Offload convolutions to the HWCE at this precision.
    pub hwce: Option<WeightPrec>,
    /// Cluster supply voltage.
    pub vdd: f64,
}

impl ExecConfig {
    pub fn sw_1core() -> Self {
        ExecConfig { n_cores: 1, simd_sw: false, hwcrypt: false, hwce: None, vdd: 0.8 }
    }
    pub fn sw_4core_simd() -> Self {
        ExecConfig { n_cores: 4, simd_sw: true, hwcrypt: false, hwce: None, vdd: 0.8 }
    }
    pub fn with_hwcrypt() -> Self {
        ExecConfig { hwcrypt: true, ..Self::sw_4core_simd() }
    }
    pub fn with_hwce(prec: WeightPrec) -> Self {
        ExecConfig { hwce: Some(prec), ..Self::with_hwcrypt() }
    }

    /// The Fig. 10-style ladder.
    pub fn ladder() -> Vec<(&'static str, ExecConfig)> {
        vec![
            ("SW 1-core", Self::sw_1core()),
            ("SW 4-core+SIMD", Self::sw_4core_simd()),
            ("+HWCRYPT", Self::with_hwcrypt()),
            ("+HWCE 16b", Self::with_hwce(WeightPrec::W16)),
            ("+HWCE 4b", Self::with_hwce(WeightPrec::W4)),
        ]
    }

    /// Operating point for convolution phases.
    pub fn conv_op(&self) -> OperatingPoint {
        let mode = if self.hwce.is_some() { OperatingMode::KecCnnSw } else { OperatingMode::Sw };
        OperatingPoint::new(mode, self.vdd)
    }

    /// Operating point for encryption phases.
    pub fn crypto_op(&self) -> OperatingPoint {
        let mode = if self.hwcrypt { OperatingMode::CryCnnSw } else { OperatingMode::Sw };
        OperatingPoint::new(mode, self.vdd)
    }

    /// Operating point for software phases.
    pub fn sw_op(&self) -> OperatingPoint {
        OperatingPoint::new(OperatingMode::Sw, self.vdd)
    }
}

/// Software convolution cost constants (cycles per MAC), measured on the VM
/// (see `kernels_sw::conv` tests; asserted against the VM in integration
/// tests): naive ≈ 94 cyc/px ÷ 25 MACs for 5×5, and the 3×3 equivalents.
pub const NAIVE_CYC_PER_MAC_5: f64 = 94.0 / 25.0;
pub const NAIVE_CYC_PER_MAC_3: f64 = 4.4;
/// SIMD 4-core: ≈13 cyc/px ÷ 25 (5×5); 3×3 has worse load/MAC ratio.
pub const SIMD4_CYC_PER_MAC_5: f64 = 13.0 / 25.0;
pub const SIMD4_CYC_PER_MAC_3: f64 = 0.65;

/// OpenRISC-1200 normalization factor: the OR1200 baseline lacks hardware
/// loops and post-increment addressing, costing ≈15 % more instructions for
/// the same kernels (§II ISA-extension discussion).
pub const OR1200_FACTOR: f64 = 1.15;

fn sw_conv_cyc_per_mac(k: usize, cfg: &ExecConfig) -> f64 {
    let (naive, simd4) = if k == 5 {
        (NAIVE_CYC_PER_MAC_5, SIMD4_CYC_PER_MAC_5)
    } else {
        (NAIVE_CYC_PER_MAC_3, SIMD4_CYC_PER_MAC_3)
    };
    if cfg.simd_sw && cfg.n_cores == 4 {
        simd4
    } else if cfg.n_cores == 1 {
        naive
    } else {
        naive / cfg.n_cores as f64 * 1.05 // near-ideal scaling + contention
    }
}

/// Result of one use-case run at one configuration.
#[derive(Debug, Clone)]
pub struct UseCaseResult {
    pub label: String,
    pub time_s: f64,
    pub energy_mj: f64,
    /// OpenRISC-1200-equivalent operations of the workload (config-invariant).
    pub eq_ops: u64,
    pub pj_per_op: f64,
    pub ledger: EnergyLedger,
}

impl UseCaseResult {
    pub fn from_ledger(label: &str, ledger: EnergyLedger, eq_ops: u64) -> Self {
        let energy_mj = ledger.total_mj();
        UseCaseResult {
            label: label.to_string(),
            time_s: ledger.elapsed_s,
            energy_mj,
            eq_ops,
            pj_per_op: energy_mj * 1e9 / eq_ops as f64,
            ledger,
        }
    }
}

/// Pipeline builder: accumulates phases onto an [`EnergyLedger`] with the
/// overlap discipline described in the module docs.
pub struct Pipeline {
    pub cfg: ExecConfig,
    pub ledger: EnergyLedger,
    /// I/O time available for overlap against the next cluster phase (s).
    io_backlog_s: f64,
    /// Mode of the previous cluster phase, to count FLL switches.
    last_mode: Option<OperatingMode>,
    pub mode_switches: u64,
    /// Whether external flash/FRAM are attached (their standby power is
    /// charged over the whole run); the pacemaker-class seizure platform
    /// has none (§IV-C).
    pub ext_mem_present: bool,
}

impl Pipeline {
    pub fn new(cfg: ExecConfig) -> Self {
        Pipeline {
            cfg,
            ledger: EnergyLedger::new(),
            io_backlog_s: 0.0,
            last_mode: None,
            mode_switches: 0,
            ext_mem_present: true,
        }
    }

    fn enter_mode(&mut self, mode: OperatingMode) {
        if self.last_mode != Some(mode) {
            if self.last_mode.is_some() {
                self.mode_switches += 1;
                self.advance_cluster(MODE_SWITCH_S, Category::Idle);
            }
            self.last_mode = Some(mode);
        }
    }

    /// Advance the cluster critical path by `dt`, consuming any pending
    /// overlappable I/O backlog, and charging baseline (leak + SOC) power.
    fn advance_cluster(&mut self, dt: f64, _cat: Category) {
        let op = OperatingPoint::new(self.last_mode.unwrap_or(OperatingMode::Sw), self.cfg.vdd);
        self.ledger.charge(Category::Idle, Component::ClusterLeak, op, dt);
        self.ledger.charge(Category::Idle, Component::SocLeak, op, dt);
        self.io_backlog_s = (self.io_backlog_s - dt).max(0.0);
        self.ledger.advance(dt);
    }

    /// A convolution phase over `macs` MACs with filter size `k`.
    /// Returns the phase time in seconds.
    pub fn conv(&mut self, macs: u64, k: usize) -> f64 {
        let op = self.cfg.conv_op();
        self.enter_mode(op.mode);
        let (cycles, n_cores_active, hwce) = match self.cfg.hwce {
            Some(prec) => {
                let cyc = macs as f64 / (k * k) as f64
                    * crate::hwce::timing::analytic_cycles_per_px(k, prec);
                (cyc, 1, true) // one controller core
            }
            None => (macs as f64 * sw_conv_cyc_per_mac(k, &self.cfg), self.cfg.n_cores, false),
        };
        let dt = cycles / op.freq_hz();
        for _ in 0..n_cores_active {
            self.ledger.charge(Category::Conv, Component::Core, op, dt);
        }
        self.ledger.charge(Category::Conv, Component::ClusterInfra, op, dt);
        if hwce {
            self.ledger.charge(Category::Conv, Component::Hwce, op, dt);
        }
        self.advance_cluster(dt, Category::Conv);
        dt
    }

    /// An AES-128-XTS phase over `bytes` (en- or decryption).
    pub fn xts(&mut self, bytes: usize) -> f64 {
        let op = self.cfg.crypto_op();
        self.enter_mode(op.mode);
        let (cycles, aes_active, n_cores) = if self.cfg.hwcrypt {
            (
                hwcrypt::CipherOp::AesXts.cycles(bytes) as f64
                    + hwcrypt::JOB_CONFIG_CYCLES as f64,
                true,
                1,
            )
        } else {
            (
                crypto_cost::sw_xts_cpb(self.cfg.n_cores) * bytes as f64,
                false,
                self.cfg.n_cores,
            )
        };
        let dt = cycles / op.freq_hz();
        for _ in 0..n_cores {
            self.ledger.charge(Category::Crypto, Component::Core, op, dt);
        }
        self.ledger.charge(Category::Crypto, Component::ClusterInfra, op, dt);
        if aes_active {
            self.ledger.charge(Category::Crypto, Component::HwcryptAes, op, dt);
        }
        self.advance_cluster(dt, Category::Crypto);
        dt
    }

    /// A sponge authenticated-encryption phase (KEC-CNN-SW capable).
    pub fn sponge_ae(&mut self, bytes: usize) -> f64 {
        let op = if self.cfg.hwcrypt {
            OperatingPoint::new(OperatingMode::KecCnnSw, self.cfg.vdd)
        } else {
            self.cfg.sw_op()
        };
        self.enter_mode(op.mode);
        let (cycles, kec_active) = if self.cfg.hwcrypt {
            (
                hwcrypt::CipherOp::SpongeAe(crate::crypto::sponge::SpongeConfig::MAX_RATE)
                    .cycles(bytes) as f64,
                true,
            )
        } else {
            (crypto_cost::SW_KECCAK_CPB_1CORE * bytes as f64, false)
        };
        let dt = cycles / op.freq_hz();
        self.ledger.charge(Category::Crypto, Component::Core, op, dt);
        self.ledger.charge(Category::Crypto, Component::ClusterInfra, op, dt);
        if kec_active {
            self.ledger.charge(Category::Crypto, Component::HwcryptKec, op, dt);
        }
        self.advance_cluster(dt, Category::Crypto);
        dt
    }

    /// A software phase of `cycles_1core` single-core cycles with a
    /// parallelizable fraction `par` (Amdahl over the config's cores).
    pub fn sw(&mut self, cycles_1core: f64, par: f64) -> f64 {
        let op = self.cfg.sw_op();
        self.enter_mode(op.mode);
        let n = self.cfg.n_cores as f64;
        let cycles = cycles_1core * ((1.0 - par) + par / n);
        let dt = cycles / op.freq_hz();
        for _ in 0..self.cfg.n_cores {
            self.ledger.charge(Category::OtherSw, Component::Core, op, dt);
        }
        self.ledger.charge(Category::OtherSw, Component::ClusterInfra, op, dt);
        self.advance_cluster(dt, Category::OtherSw);
        dt
    }

    /// Cluster-DMA staging of `bytes` L2↔TCDM — double-buffered, so only
    /// the excess over the already-elapsed compute backlog appears on the
    /// critical path. Energy is always charged.
    pub fn dma(&mut self, bytes: usize) {
        let op = OperatingPoint::new(self.last_mode.unwrap_or(OperatingMode::Sw), self.cfg.vdd);
        let dt = bytes as f64 / 8.0 / op.freq_hz(); // 8 B/cycle AXI
        self.ledger.charge(Category::Dma, Component::ClusterInfra, op, dt);
        // DMA overlaps compute: extend the critical path only beyond backlog.
        self.io_backlog_s += dt;
    }

    /// External-memory traffic over the uDMA (flash or FRAM), overlapped
    /// with cluster compute via double buffering.
    pub fn extmem(&mut self, device: crate::extmem::Device, bytes: usize) {
        let dt = bytes as f64 / device.bandwidth_bps();
        let comp = match device {
            crate::extmem::Device::Flash => Component::Flash,
            crate::extmem::Device::Fram => Component::Fram,
        };
        let op = OperatingPoint::new(self.last_mode.unwrap_or(OperatingMode::Sw), self.cfg.vdd);
        self.ledger.charge(Category::ExtMem, comp, op, dt);
        self.ledger.charge(Category::ExtMem, Component::SocDomain, op, dt);
        self.io_backlog_s += dt;
    }

    /// Finish the pipeline: any I/O backlog that could not be hidden behind
    /// compute lands on the critical path; external-memory standby power is
    /// charged over the whole run.
    pub fn finish(mut self) -> EnergyLedger {
        if self.io_backlog_s > 0.0 {
            let dt = self.io_backlog_s;
            self.advance_cluster(dt, Category::ExtMem);
        }
        if self.ext_mem_present {
            let standby_mw =
                crate::soc::power::FLASH_STANDBY_MW + crate::soc::power::FRAM_STANDBY_MW;
            let total = self.ledger.elapsed_s;
            self.ledger.charge_mj(Category::ExtMem, standby_mw * total);
        }
        self.ledger
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_has_five_rungs() {
        let l = ExecConfig::ladder();
        assert_eq!(l.len(), 5);
        assert_eq!(l[0].1.n_cores, 1);
        assert!(l[4].1.hwce == Some(WeightPrec::W4));
    }

    #[test]
    fn hwce_conv_much_faster_than_sw() {
        let macs = 100_000_000u64;
        let mut sw = Pipeline::new(ExecConfig::sw_1core());
        let t_sw = sw.conv(macs, 3);
        let mut hw = Pipeline::new(ExecConfig::with_hwce(WeightPrec::W16));
        let t_hw = hw.conv(macs, 3);
        let speedup = t_sw / t_hw;
        // §III-C: 82× vs naive single core (the mode-frequency difference
        // trims it slightly; anything 40–90 is the right shape)
        assert!(speedup > 25.0 && speedup < 100.0, "speedup {speedup}");
    }

    #[test]
    fn hwcrypt_xts_much_faster_than_sw() {
        let bytes = 1 << 20;
        let mut sw = Pipeline::new(ExecConfig::sw_1core());
        let t_sw = sw.xts(bytes);
        let mut hw = Pipeline::new(ExecConfig::with_hwcrypt());
        let t_hw = hw.xts(bytes);
        let speedup = t_sw / t_hw;
        assert!(speedup > 200.0 && speedup < 600.0, "speedup {speedup}");
    }

    #[test]
    fn mode_switch_counted_and_costed() {
        let mut p = Pipeline::new(ExecConfig::with_hwce(WeightPrec::W4));
        p.conv(1_000_000, 3); // KEC mode
        p.xts(1024); // CRY mode — switch
        p.conv(1_000_000, 3); // back — switch
        assert_eq!(p.mode_switches, 2);
    }

    #[test]
    fn io_overlaps_compute() {
        let cfg = ExecConfig::with_hwce(WeightPrec::W4);
        // compute-dominated: extmem fully hidden
        let mut a = Pipeline::new(cfg);
        a.conv(500_000_000, 3);
        a.extmem(crate::extmem::Device::Fram, 1024);
        let la = a.finish();
        let mut b = Pipeline::new(cfg);
        b.conv(500_000_000, 3);
        let lb = b.finish();
        assert!((la.elapsed_s - lb.elapsed_s).abs() / lb.elapsed_s < 0.01);
        // io-dominated: backlog lands on the critical path
        let mut c = Pipeline::new(cfg);
        c.conv(1_000, 3);
        c.extmem(crate::extmem::Device::Fram, 10 << 20);
        let lc = c.finish();
        assert!(lc.elapsed_s > 0.4, "10 MB at 20 MB/s must take ≥0.5 s");
    }

    #[test]
    fn sw_phase_amdahl() {
        let mut p1 = Pipeline::new(ExecConfig::sw_1core());
        let t1 = p1.sw(1e9, 0.9);
        let mut p4 = Pipeline::new(ExecConfig::sw_4core_simd());
        let t4 = p4.sw(1e9, 0.9);
        let s = t1 / t4;
        assert!((s - 1.0 / (0.1 + 0.9 / 4.0)).abs() < 0.05, "amdahl {s}");
    }

    #[test]
    fn energy_breakdown_populated() {
        let mut p = Pipeline::new(ExecConfig::with_hwce(WeightPrec::W4));
        p.conv(10_000_000, 3);
        p.xts(100_000);
        p.sw(1e6, 1.0);
        p.extmem(crate::extmem::Device::Flash, 100_000);
        let l = p.finish();
        for cat in [Category::Conv, Category::Crypto, Category::OtherSw, Category::ExtMem] {
            assert!(l.energy_mj(cat) > 0.0, "{cat:?} empty");
        }
        assert!(l.total_mj() > 0.0 && l.elapsed_s > 0.0);
    }
}
