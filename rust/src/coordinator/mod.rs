//! The application coordinator: expresses the secure-analytics pipelines of
//! §IV as *job graphs* over the simulated SoC's engines (cores, HWCE,
//! HWCRYPT, cluster DMA, uDMA channels to the external memories) and runs
//! them on the event-driven scheduler ([`crate::soc::sched`]).
//!
//! Each use case emits a [`JobGraph`] via the [`GraphBuilder`], whose phase
//! methods carry the calibrated service-time models (§III measurements) and
//! per-component energy charges; the paper's execution discipline (§II-D)
//! then *emerges from the schedule* instead of being hand-approximated:
//!
//! * tiles sized to the 64 kB TCDM, staged L2↔TCDM by the cluster DMA,
//!   which runs concurrently with compute (double buffering);
//! * I/O and external memories served by per-interface uDMA channels that
//!   prefetch as early as their data dependencies allow;
//! * HWCE and HWCRYPT phases serialize when their operating modes differ
//!   (shared cluster clock) and overlap when they don't;
//! * operating-mode switches cost the 10 µs FLL relock (§II-A), counted by
//!   the scheduler as the mode lock changes hands.
//!
//! Each use case produces a [`UseCaseResult`] with the same breakdown
//! categories as Fig. 10/11/12 and the paper's pJ-per-equivalent-RISC-op
//! metric (OpenRISC-1200-normalized op counts; footnote 4), plus a
//! [`StreamResult`] for the multi-frame streaming mode (`fulmine stream`)
//! that pipelines successive frames through the same graph.
//!
//! The pre-scheduler analytic model (phase times summed on the cluster
//! critical path, I/O hidden up to an overlap backlog) survives as
//! [`JobGraph::analytic`]; `rust/tests/scheduler.rs` pins the scheduled
//! results to it within 5 % so the Fig. 10/11/12 reports stay faithful.

pub mod facedet;
pub mod seizure;
pub mod surveillance;

use crate::energy::{Category, EnergyLedger};
use crate::extmem::Device;
use crate::hwce::golden::WeightPrec;
use crate::hwcrypt;
use crate::kernels_sw::crypto_cost;
use crate::soc::opmodes::{OperatingMode, OperatingPoint};
use crate::soc::power::Component;
use crate::soc::sched::{Engine, Job, JobGraph, JobId, Scheduler};

/// One labeled rung of a workload's configuration ladder (Fig. 10/11/12):
/// the typed replacement for the former `(&'static str, ExecConfig)` tuples.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Rung {
    pub label: &'static str,
    pub cfg: ExecConfig,
}

/// Optional per-run overrides on top of a selected [`Rung`]'s
/// [`ExecConfig`] — how a [`crate::system::RunSpec`] expresses ablations
/// (swap the HWCE precision, drop the HWCRYPT, raise VDD) without
/// inventing new rungs.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ModeOverrides {
    pub n_cores: Option<usize>,
    pub simd_sw: Option<bool>,
    pub hwcrypt: Option<bool>,
    /// `Some(None)` forces software convolution; `Some(Some(prec))` forces
    /// the HWCE at that precision.
    pub hwce: Option<Option<WeightPrec>>,
    pub vdd: Option<f64>,
}

impl ModeOverrides {
    pub fn apply(&self, cfg: ExecConfig) -> ExecConfig {
        ExecConfig {
            n_cores: self.n_cores.unwrap_or(cfg.n_cores),
            simd_sw: self.simd_sw.unwrap_or(cfg.simd_sw),
            hwcrypt: self.hwcrypt.unwrap_or(cfg.hwcrypt),
            hwce: self.hwce.unwrap_or(cfg.hwce),
            vdd: self.vdd.unwrap_or(cfg.vdd),
        }
    }
}

/// Execution configuration — one rung of the Fig. 10/11/12 ladder.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ExecConfig {
    /// Active cores for software kernels.
    pub n_cores: usize,
    /// Use the SIMD-optimized software kernels.
    pub simd_sw: bool,
    /// Offload encryption to the HWCRYPT.
    pub hwcrypt: bool,
    /// Offload convolutions to the HWCE at this precision.
    pub hwce: Option<WeightPrec>,
    /// Cluster supply voltage.
    pub vdd: f64,
}

impl ExecConfig {
    pub fn sw_1core() -> Self {
        ExecConfig { n_cores: 1, simd_sw: false, hwcrypt: false, hwce: None, vdd: 0.8 }
    }
    pub fn sw_4core_simd() -> Self {
        ExecConfig { n_cores: 4, simd_sw: true, hwcrypt: false, hwce: None, vdd: 0.8 }
    }
    pub fn with_hwcrypt() -> Self {
        ExecConfig { hwcrypt: true, ..Self::sw_4core_simd() }
    }
    pub fn with_hwce(prec: WeightPrec) -> Self {
        ExecConfig { hwce: Some(prec), ..Self::with_hwcrypt() }
    }

    /// The Fig. 10-style ladder.
    pub fn ladder() -> Vec<Rung> {
        vec![
            Rung { label: "SW 1-core", cfg: Self::sw_1core() },
            Rung { label: "SW 4-core+SIMD", cfg: Self::sw_4core_simd() },
            Rung { label: "+HWCRYPT", cfg: Self::with_hwcrypt() },
            Rung { label: "+HWCE 16b", cfg: Self::with_hwce(WeightPrec::W16) },
            Rung { label: "+HWCE 4b", cfg: Self::with_hwce(WeightPrec::W4) },
        ]
    }

    /// Operating point for convolution phases.
    pub fn conv_op(&self) -> OperatingPoint {
        let mode = if self.hwce.is_some() { OperatingMode::KecCnnSw } else { OperatingMode::Sw };
        OperatingPoint::new(mode, self.vdd)
    }

    /// Operating point for encryption phases.
    pub fn crypto_op(&self) -> OperatingPoint {
        let mode = if self.hwcrypt { OperatingMode::CryCnnSw } else { OperatingMode::Sw };
        OperatingPoint::new(mode, self.vdd)
    }

    /// Operating point for software phases.
    pub fn sw_op(&self) -> OperatingPoint {
        OperatingPoint::new(OperatingMode::Sw, self.vdd)
    }
}

/// Software convolution cost constants (cycles per MAC), measured on the VM
/// (see `kernels_sw::conv` tests; asserted against the VM in integration
/// tests): naive ≈ 94 cyc/px ÷ 25 MACs for 5×5, and the 3×3 equivalents.
pub const NAIVE_CYC_PER_MAC_5: f64 = 94.0 / 25.0;
pub const NAIVE_CYC_PER_MAC_3: f64 = 4.4;
/// SIMD 4-core: ≈13 cyc/px ÷ 25 (5×5); 3×3 has worse load/MAC ratio.
pub const SIMD4_CYC_PER_MAC_5: f64 = 13.0 / 25.0;
pub const SIMD4_CYC_PER_MAC_3: f64 = 0.65;

/// OpenRISC-1200 normalization factor: the OR1200 baseline lacks hardware
/// loops and post-increment addressing, costing ≈15 % more instructions for
/// the same kernels (§II ISA-extension discussion).
pub const OR1200_FACTOR: f64 = 1.15;

fn sw_conv_cyc_per_mac(k: usize, cfg: &ExecConfig) -> f64 {
    let (naive, simd4) = if k == 5 {
        (NAIVE_CYC_PER_MAC_5, SIMD4_CYC_PER_MAC_5)
    } else {
        (NAIVE_CYC_PER_MAC_3, SIMD4_CYC_PER_MAC_3)
    };
    if cfg.simd_sw && cfg.n_cores == 4 {
        simd4
    } else if cfg.n_cores == 1 {
        naive
    } else {
        naive / cfg.n_cores as f64 * 1.05 // near-ideal scaling + contention
    }
}

/// Result of one use-case run at one configuration.
#[derive(Debug, Clone)]
pub struct UseCaseResult {
    pub label: String,
    pub time_s: f64,
    pub energy_mj: f64,
    /// OpenRISC-1200-equivalent operations of the workload (config-invariant).
    pub eq_ops: u64,
    pub pj_per_op: f64,
    pub ledger: EnergyLedger,
}

impl UseCaseResult {
    pub fn from_ledger(label: &str, ledger: EnergyLedger, eq_ops: u64) -> Self {
        let energy_mj = ledger.total_mj();
        UseCaseResult {
            label: label.to_string(),
            time_s: ledger.elapsed_s,
            energy_mj,
            eq_ops,
            pj_per_op: energy_mj * 1e9 / eq_ops as f64,
            ledger,
        }
    }
}

/// Result of streaming `frames` successive frames through a use-case graph.
#[derive(Debug, Clone)]
pub struct StreamResult {
    pub label: String,
    pub frames: usize,
    /// Makespan of the streamed schedule (s).
    pub time_s: f64,
    /// Throughput, frames per second.
    pub fps: f64,
    /// Total energy over all frames (mJ).
    pub energy_mj: f64,
    /// Energy per equivalent RISC op, over all frames.
    pub pj_per_op: f64,
    /// Makespan of a single scheduled frame (s).
    pub single_frame_s: f64,
    /// Throughput gain over `frames` back-to-back single-frame runs.
    pub speedup: f64,
    pub mode_switches: u64,
    /// Per-engine busy time of the streamed schedule (s), indexed by
    /// [`Engine::index`].
    pub busy_s: [f64; crate::soc::sched::N_ENGINES],
    pub ledger: EnergyLedger,
}

/// Run `graph` single-frame and `frames`-deep and package the comparison.
pub fn stream_graph(
    label: &str,
    graph: &JobGraph,
    frames: usize,
    eq_ops_per_frame: u64,
) -> StreamResult {
    assert!(frames >= 1, "streaming needs at least one frame");
    let single = Scheduler::run(graph);
    let res = Scheduler::run(&graph.repeat(frames));
    let energy_mj = res.ledger.total_mj();
    StreamResult {
        label: label.to_string(),
        frames,
        time_s: res.makespan_s,
        fps: frames as f64 / res.makespan_s,
        energy_mj,
        pj_per_op: energy_mj * 1e9 / (eq_ops_per_frame as f64 * frames as f64),
        single_frame_s: single.makespan_s,
        speedup: single.makespan_s * frames as f64 / res.makespan_s,
        mode_switches: res.mode_switches,
        busy_s: res.busy_s,
        ledger: res.ledger,
    }
}

/// Builds a [`JobGraph`] phase by phase. Each method mirrors one phase kind
/// of the paper's pipelines, computing its engine, service time (from the
/// §III-calibrated cycle models) and energy charges from the [`ExecConfig`];
/// dependencies are explicit job ids returned by earlier calls.
pub struct GraphBuilder {
    pub cfg: ExecConfig,
    graph: JobGraph,
    /// Mode of the most recently emitted cluster job — DMA transfers run on
    /// the cluster clock, so their service time and charge follow it (the
    /// same convention the analytic model used).
    emission_mode: Option<OperatingMode>,
}

impl GraphBuilder {
    pub fn new(cfg: ExecConfig) -> Self {
        GraphBuilder { cfg, graph: JobGraph::new(), emission_mode: None }
    }

    /// Detach the external flash/FRAM (no standby charge) — §IV-C.
    pub fn set_ext_mem_present(&mut self, present: bool) {
        self.graph.ext_mem_present = present;
    }

    /// Whether the external memories are currently attached.
    pub fn ext_mem_present(&self) -> bool {
        self.graph.ext_mem_present
    }

    /// Jobs emitted so far.
    pub fn len(&self) -> usize {
        self.graph.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graph.is_empty()
    }

    /// Open a named segment (e.g. one tenant of a mixed multi-tenant
    /// workload) — see [`JobGraph::mark_segment`].
    pub fn begin_segment(&mut self, label: &str) {
        self.graph.mark_segment(label);
    }

    pub fn build(self) -> JobGraph {
        self.graph
    }

    /// Operating point for SOC-side movers: the cluster clock at the mode
    /// of the last cluster phase.
    fn mover_op(&self) -> OperatingPoint {
        OperatingPoint::new(self.emission_mode.unwrap_or(OperatingMode::Sw), self.cfg.vdd)
    }

    fn push(
        &mut self,
        label: &'static str,
        engine: Engine,
        op: OperatingPoint,
        duration_s: f64,
        deps: &[JobId],
        charges: Vec<(Category, Component, f64)>,
    ) -> JobId {
        if engine.mode_locked() {
            self.emission_mode = Some(op.mode);
        }
        self.graph.push(Job { label, engine, op, duration_s, deps: deps.to_vec(), charges })
    }

    /// A convolution phase over `macs` MACs with filter size `k` — on the
    /// HWCE (plus one controller core) or on the software cores.
    pub fn conv(&mut self, macs: u64, k: usize, deps: &[JobId]) -> JobId {
        let op = self.cfg.conv_op();
        let (cycles, engine, charges) = match self.cfg.hwce {
            Some(prec) => (
                macs as f64 / (k * k) as f64 * crate::hwce::timing::analytic_cycles_per_px(k, prec),
                Engine::Hwce,
                vec![
                    (Category::Conv, Component::Core, 1.0), // controller core
                    (Category::Conv, Component::ClusterInfra, 1.0),
                    (Category::Conv, Component::Hwce, 1.0),
                ],
            ),
            None => (
                macs as f64 * sw_conv_cyc_per_mac(k, &self.cfg),
                Engine::Cores,
                vec![
                    (Category::Conv, Component::Core, self.cfg.n_cores as f64),
                    (Category::Conv, Component::ClusterInfra, 1.0),
                ],
            ),
        };
        self.push("conv", engine, op, cycles / op.freq_hz(), deps, charges)
    }

    /// An AES-128-XTS phase over `bytes` (en- or decryption).
    pub fn xts(&mut self, bytes: usize, deps: &[JobId]) -> JobId {
        let op = self.cfg.crypto_op();
        let (cycles, engine, charges) = if self.cfg.hwcrypt {
            (
                hwcrypt::CipherOp::AesXts.cycles(bytes) as f64 + hwcrypt::JOB_CONFIG_CYCLES as f64,
                Engine::HwcryptAes,
                vec![
                    (Category::Crypto, Component::Core, 1.0), // controller core
                    (Category::Crypto, Component::ClusterInfra, 1.0),
                    (Category::Crypto, Component::HwcryptAes, 1.0),
                ],
            )
        } else {
            (
                crypto_cost::sw_xts_cpb(self.cfg.n_cores) * bytes as f64,
                Engine::Cores,
                vec![
                    (Category::Crypto, Component::Core, self.cfg.n_cores as f64),
                    (Category::Crypto, Component::ClusterInfra, 1.0),
                ],
            )
        };
        self.push("xts", engine, op, cycles / op.freq_hz(), deps, charges)
    }

    /// A sponge authenticated-encryption phase (KEC-CNN-SW capable).
    pub fn sponge_ae(&mut self, bytes: usize, deps: &[JobId]) -> JobId {
        let (op, cycles, engine, charges) = if self.cfg.hwcrypt {
            (
                OperatingPoint::new(OperatingMode::KecCnnSw, self.cfg.vdd),
                hwcrypt::CipherOp::SpongeAe(crate::crypto::sponge::SpongeConfig::MAX_RATE)
                    .cycles(bytes) as f64,
                Engine::HwcryptKec,
                vec![
                    (Category::Crypto, Component::Core, 1.0),
                    (Category::Crypto, Component::ClusterInfra, 1.0),
                    (Category::Crypto, Component::HwcryptKec, 1.0),
                ],
            )
        } else {
            (
                self.cfg.sw_op(),
                crypto_cost::SW_KECCAK_CPB_1CORE * bytes as f64,
                Engine::Cores,
                vec![
                    (Category::Crypto, Component::Core, 1.0),
                    (Category::Crypto, Component::ClusterInfra, 1.0),
                ],
            )
        };
        self.push("sponge-ae", engine, op, cycles / op.freq_hz(), deps, charges)
    }

    /// A software phase of `cycles_1core` single-core cycles with a
    /// parallelizable fraction `par` (Amdahl over the config's cores).
    pub fn sw(&mut self, cycles_1core: f64, par: f64, deps: &[JobId]) -> JobId {
        let op = self.cfg.sw_op();
        let n = self.cfg.n_cores as f64;
        let cycles = cycles_1core * ((1.0 - par) + par / n);
        self.push(
            "sw",
            Engine::Cores,
            op,
            cycles / op.freq_hz(),
            deps,
            vec![
                (Category::OtherSw, Component::Core, n),
                (Category::OtherSw, Component::ClusterInfra, 1.0),
            ],
        )
    }

    /// Cluster-DMA staging of `bytes` L2↔TCDM (8 B/cycle AXI), concurrent
    /// with compute on its own engine.
    pub fn dma(&mut self, bytes: usize, deps: &[JobId]) -> JobId {
        let op = self.mover_op();
        let duration = bytes as f64 / 8.0 / op.freq_hz();
        self.push(
            "dma",
            Engine::ClusterDma,
            op,
            duration,
            deps,
            vec![(Category::Dma, Component::ClusterInfra, 1.0)],
        )
    }

    /// External-memory traffic over the device's uDMA channel (flash or
    /// FRAM), concurrent with cluster compute.
    pub fn extmem(&mut self, device: Device, bytes: usize, deps: &[JobId]) -> JobId {
        let (engine, comp) = match device {
            Device::Flash => (Engine::UdmaFlash, Component::Flash),
            Device::Fram => (Engine::UdmaFram, Component::Fram),
        };
        let op = self.mover_op();
        let duration = bytes as f64 / device.bandwidth_bps();
        self.push(
            "extmem",
            engine,
            op,
            duration,
            deps,
            vec![(Category::ExtMem, comp, 1.0), (Category::ExtMem, Component::SocDomain, 1.0)],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Makespan of a single-phase graph built by `f`.
    fn phase_time(cfg: ExecConfig, f: impl FnOnce(&mut GraphBuilder) -> JobId) -> f64 {
        let mut b = GraphBuilder::new(cfg);
        f(&mut b);
        Scheduler::run(&b.build()).makespan_s
    }

    #[test]
    fn ladder_has_five_rungs() {
        let l = ExecConfig::ladder();
        assert_eq!(l.len(), 5);
        assert_eq!(l[0].cfg.n_cores, 1);
        assert!(l[4].cfg.hwce == Some(WeightPrec::W4));
    }

    #[test]
    fn overrides_apply_field_by_field() {
        let base = ExecConfig::with_hwce(WeightPrec::W4);
        assert_eq!(ModeOverrides::default().apply(base), base);
        let o = ModeOverrides { hwcrypt: Some(false), vdd: Some(1.2), ..Default::default() };
        let cfg = o.apply(base);
        assert!(!cfg.hwcrypt);
        assert_eq!(cfg.vdd, 1.2);
        assert_eq!(cfg.hwce, base.hwce);
        assert_eq!(cfg.n_cores, base.n_cores);
        let sw = ModeOverrides { hwce: Some(None), ..Default::default() }.apply(base);
        assert_eq!(sw.hwce, None);
    }

    #[test]
    fn hwce_conv_much_faster_than_sw() {
        let macs = 100_000_000u64;
        let t_sw = phase_time(ExecConfig::sw_1core(), |b| b.conv(macs, 3, &[]));
        let t_hw = phase_time(ExecConfig::with_hwce(WeightPrec::W16), |b| b.conv(macs, 3, &[]));
        let speedup = t_sw / t_hw;
        // §III-C: 82× vs naive single core (the mode-frequency difference
        // trims it slightly; anything 40–90 is the right shape)
        assert!(speedup > 25.0 && speedup < 100.0, "speedup {speedup}");
    }

    #[test]
    fn hwcrypt_xts_much_faster_than_sw() {
        let bytes = 1 << 20;
        let t_sw = phase_time(ExecConfig::sw_1core(), |b| b.xts(bytes, &[]));
        let t_hw = phase_time(ExecConfig::with_hwcrypt(), |b| b.xts(bytes, &[]));
        let speedup = t_sw / t_hw;
        assert!(speedup > 200.0 && speedup < 600.0, "speedup {speedup}");
    }

    #[test]
    fn mode_switch_counted_and_costed() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c1 = b.conv(1_000_000, 3, &[]); // KEC mode
        let x = b.xts(1024, &[c1]); // CRY mode — switch
        b.conv(1_000_000, 3, &[x]); // back — switch
        let r = Scheduler::run(&b.build());
        assert_eq!(r.mode_switches, 2);
    }

    #[test]
    fn io_overlaps_compute() {
        let cfg = ExecConfig::with_hwce(WeightPrec::W4);
        // compute-dominated: a prefetchable ext-mem transfer is fully hidden
        let mut a = GraphBuilder::new(cfg);
        a.conv(500_000_000, 3, &[]);
        a.extmem(Device::Fram, 1024, &[]);
        let ta = Scheduler::run(&a.build()).makespan_s;
        let tb = phase_time(cfg, |b| b.conv(500_000_000, 3, &[]));
        assert!((ta - tb).abs() / tb < 0.01);
        // io-dominated: the transfer is the critical path
        let mut c = GraphBuilder::new(cfg);
        c.conv(1_000, 3, &[]);
        c.extmem(Device::Fram, 10 << 20, &[]);
        let tc = Scheduler::run(&c.build()).makespan_s;
        assert!(tc > 0.4, "10 MB at 20 MB/s must take ≥0.5 s");
    }

    #[test]
    fn sw_phase_amdahl() {
        let t1 = phase_time(ExecConfig::sw_1core(), |b| b.sw(1e9, 0.9, &[]));
        let t4 = phase_time(ExecConfig::sw_4core_simd(), |b| b.sw(1e9, 0.9, &[]));
        let s = t1 / t4;
        assert!((s - 1.0 / (0.1 + 0.9 / 4.0)).abs() < 0.05, "amdahl {s}");
    }

    #[test]
    fn energy_breakdown_populated() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c = b.conv(10_000_000, 3, &[]);
        let x = b.xts(100_000, &[c]);
        b.sw(1e6, 1.0, &[x]);
        b.extmem(Device::Flash, 100_000, &[]);
        let l = Scheduler::run(&b.build()).ledger;
        for cat in [Category::Conv, Category::Crypto, Category::OtherSw, Category::ExtMem] {
            assert!(l.energy_mj(cat) > 0.0, "{cat:?} empty");
        }
        assert!(l.total_mj() > 0.0 && l.elapsed_s > 0.0);
    }

    /// The scheduled and analytic models agree exactly on a serial chain
    /// whose I/O fits under compute — the calibration contract.
    #[test]
    fn scheduled_matches_analytic_on_serial_chain() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c = b.conv(50_000_000, 3, &[]);
        let s = b.sw(1e6, 1.0, &[c]);
        let x = b.xts(100_000, &[s]);
        b.dma(64 * 1024, &[x]);
        let g = b.build();
        let run = Scheduler::run(&g);
        let ana = g.analytic();
        assert!((run.makespan_s - ana.makespan_s).abs() / ana.makespan_s < 1e-9);
        assert_eq!(run.mode_switches, ana.mode_switches);
        assert!((run.ledger.total_mj() - ana.ledger.total_mj()).abs() < 1e-9);
    }

    #[test]
    fn stream_result_consistent() {
        let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
        let c = b.conv(10_000_000, 3, &[]);
        let x = b.xts(100_000, &[c]);
        b.extmem(Device::Fram, 200_000, &[x]);
        let g = b.build();
        let r = stream_graph("test", &g, 4, 1_000_000);
        assert_eq!(r.frames, 4);
        assert!(r.time_s > 0.0 && r.fps > 0.0);
        assert!((r.fps - 4.0 / r.time_s).abs() < 1e-9);
        assert!(r.speedup >= 0.99, "streaming slower than serial: {}", r.speedup);
        assert!(r.time_s >= r.single_frame_s - 1e-12);
    }
}
