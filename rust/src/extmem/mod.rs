//! External memory device models (§IV, Fig. 9): two banks (16 MB) of
//! Microchip SST26VF064 quad-SPI flash holding CNN weights, and 2 MB of
//! Cypress CY15B104Q ferroelectric RAM (four banks, bit-interleaved to reach
//! quad-SPI bandwidth) holding partial results.
//!
//! Both are *untrusted* in the paper's threat model: everything stored there
//! is AES-128-XTS encrypted, the Fulmine cluster being "the only secure
//! enclave in which decrypted data can reside" (§IV-A). The models provide
//! functional storage plus transfer-time/energy accounting.

use crate::crypto::modes::{self, XtsKey};
use crate::energy::{Category, EnergyLedger};
use crate::soc::power::{FLASH_ACTIVE_MW, FLASH_BW_BPS, FRAM_ACTIVE_MW, FRAM_BW_BPS};

/// XTS sector size used for external-memory protection. The paper derives
/// the sector number "from the address of the data"; 512 B sectors keep
/// random access to tiles cheap.
pub const SECTOR_BYTES: usize = 512;

/// Flash capacity: 2 × 8 MB banks.
pub const FLASH_BYTES: usize = 16 << 20;
/// FRAM capacity: 4 × 512 kB banks.
pub const FRAM_BYTES: usize = 2 << 20;

/// Device kind, selecting bandwidth/power constants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Device {
    Flash,
    Fram,
}

impl Device {
    pub fn bandwidth_bps(self) -> f64 {
        match self {
            Device::Flash => FLASH_BW_BPS,
            Device::Fram => FRAM_BW_BPS,
        }
    }

    pub fn active_mw(self) -> f64 {
        match self {
            Device::Flash => FLASH_ACTIVE_MW,
            Device::Fram => FRAM_ACTIVE_MW,
        }
    }

    pub fn capacity(self) -> usize {
        match self {
            Device::Flash => FLASH_BYTES,
            Device::Fram => FRAM_BYTES,
        }
    }
}

/// An external memory holding ciphertext, addressed by byte offset.
/// Writes must be sector-aligned multiples (as XTS sectors are the
/// en/decryption unit).
pub struct ExtMem {
    pub device: Device,
    data: Vec<u8>,
}

impl ExtMem {
    pub fn new(device: Device) -> Self {
        ExtMem { device, data: vec![0xff; device.capacity()] }
    }

    /// Store `plaintext` XTS-encrypted at byte offset `off` (sector-aligned).
    /// Charges transfer time/energy to `ledger` if provided.
    pub fn store_encrypted(
        &mut self,
        key: &XtsKey,
        off: usize,
        plaintext: &[u8],
        ledger: Option<&mut EnergyLedger>,
    ) {
        assert!(off % SECTOR_BYTES == 0, "unaligned external store");
        assert!(off + plaintext.len() <= self.data.len(), "ext mem overflow");
        let base_sector = (off / SECTOR_BYTES) as u128;
        let ct = modes::xts_encrypt_region(key, base_sector, SECTOR_BYTES, plaintext);
        self.data[off..off + ct.len()].copy_from_slice(&ct);
        if let Some(l) = ledger {
            self.charge_transfer(l, plaintext.len());
        }
    }

    /// Load and XTS-decrypt `len` bytes from offset `off`.
    pub fn load_decrypted(
        &self,
        key: &XtsKey,
        off: usize,
        len: usize,
        ledger: Option<&mut EnergyLedger>,
    ) -> Vec<u8> {
        assert!(off % SECTOR_BYTES == 0, "unaligned external load");
        let base_sector = (off / SECTOR_BYTES) as u128;
        let pt = modes::xts_decrypt_region(key, base_sector, SECTOR_BYTES, &self.data[off..off + len]);
        if let Some(l) = ledger {
            self.charge_transfer(l, len);
        }
        pt
    }

    /// Raw ciphertext access (what an attacker probing the SPI bus sees).
    pub fn raw(&self, off: usize, len: usize) -> &[u8] {
        &self.data[off..off + len]
    }

    /// Tamper with stored ciphertext (fault-injection tests).
    pub fn corrupt(&mut self, off: usize, xor: u8) {
        self.data[off] ^= xor;
    }

    /// Transfer time in seconds for `bytes` over this device's interface.
    pub fn transfer_s(&self, bytes: usize) -> f64 {
        bytes as f64 / self.device.bandwidth_bps()
    }

    fn charge_transfer(&self, ledger: &mut EnergyLedger, bytes: usize) {
        let t = self.transfer_s(bytes);
        ledger.charge_mj(Category::ExtMem, self.device.active_mw() * t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> XtsKey {
        XtsKey::new(&[0xaa; 16], &[0x55; 16])
    }

    #[test]
    fn encrypted_roundtrip() {
        let mut m = ExtMem::new(Device::Fram);
        let data: Vec<u8> = (0..4096).map(|i| (i % 253) as u8).collect();
        m.store_encrypted(&key(), 1024, &data, None);
        let back = m.load_decrypted(&key(), 1024, data.len(), None);
        assert_eq!(back, data);
    }

    #[test]
    fn ciphertext_differs_from_plaintext() {
        let mut m = ExtMem::new(Device::Flash);
        let data = vec![0u8; SECTOR_BYTES];
        m.store_encrypted(&key(), 0, &data, None);
        assert_ne!(m.raw(0, SECTOR_BYTES), &data[..]);
        // equal sectors at different offsets yield different ciphertext (XTS)
        m.store_encrypted(&key(), SECTOR_BYTES, &data, None);
        assert_ne!(m.raw(0, SECTOR_BYTES), m.raw(SECTOR_BYTES, SECTOR_BYTES));
    }

    #[test]
    fn corruption_scrambles_decryption() {
        let mut m = ExtMem::new(Device::Fram);
        let data = vec![7u8; SECTOR_BYTES];
        m.store_encrypted(&key(), 0, &data, None);
        m.corrupt(100, 0x01);
        let back = m.load_decrypted(&key(), 0, SECTOR_BYTES, None);
        assert_ne!(back, data, "XTS must not silently absorb tampering");
    }

    #[test]
    fn transfer_energy_charged() {
        let mut m = ExtMem::new(Device::Flash);
        let mut ledger = EnergyLedger::new();
        let data = vec![1u8; 1 << 20];
        m.store_encrypted(&key(), 0, &data, Some(&mut ledger));
        // 1 MB at 40 MB/s = 26.2 ms at 54 mW ≈ 1.41 mJ
        let e = ledger.energy_mj(Category::ExtMem);
        assert!((e - 1.41).abs() < 0.1, "flash energy {e} mJ");
    }

    #[test]
    fn wrong_key_fails_roundtrip() {
        let mut m = ExtMem::new(Device::Fram);
        let data = vec![42u8; SECTOR_BYTES];
        m.store_encrypted(&key(), 0, &data, None);
        let other = XtsKey::new(&[1; 16], &[2; 16]);
        assert_ne!(m.load_decrypted(&other, 0, SECTOR_BYTES, None), data);
    }
}
