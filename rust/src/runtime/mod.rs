//! PJRT runtime: loads the AOT artifacts produced by `python/compile/aot.py`
//! (HLO *text* — see aot_recipe and /opt/xla-example) and executes them on
//! the CPU PJRT client from the L3 hot path. Python never runs here.
//!
//! The [`Runtime`] keeps a lazy compile cache: each artifact is compiled at
//! most once per process and re-executed for every tile/inference. All
//! tensors are int16 fixed point (the HWCE data format).

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

// The real PJRT bindings need the XLA C library, which the offline build
// environment does not provide. Default builds use a stub with the same
// surface that fails at client creation with a clear message; enabling the
// `pjrt` feature (plus adding the `xla` bindings crate to Cargo.toml)
// switches to the real path without touching this module's code.
#[cfg(not(feature = "pjrt"))]
use self::pjrt_stub as xla;

// The offline registry does not carry the `xla` bindings, so the feature
// cannot declare the dependency itself. Turn the otherwise-cryptic
// unresolved-crate errors into one actionable message.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature needs the `xla` bindings crate (and the XLA C library): \
     add `xla` to [dependencies] in Cargo.toml, then delete this compile_error! \
     in rust/src/runtime/mod.rs"
);

/// Metadata for one AOT artifact, parsed from `artifacts/manifest.txt`
/// (line format: `name|file|kind|k|simd|qf|shape;shape;...`).
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub k: usize,
    pub simd: usize,
    pub qf: u8,
    /// Input tensor shapes, in call order.
    pub input_shapes: Vec<Vec<usize>>,
}

impl ArtifactMeta {
    fn parse(line: &str) -> Result<Self> {
        let parts: Vec<&str> = line.trim().split('|').collect();
        if parts.len() != 7 {
            bail!("malformed manifest line: {line}");
        }
        let input_shapes = parts[6]
            .split(';')
            .map(|s| {
                if s == "scalar" {
                    Ok(vec![])
                } else {
                    s.split('x').map(|d| d.parse::<usize>().map_err(|e| anyhow!("{e}"))).collect()
                }
            })
            .collect::<Result<Vec<Vec<usize>>>>()?;
        Ok(ArtifactMeta {
            name: parts[0].to_string(),
            file: parts[1].to_string(),
            kind: parts[2].to_string(),
            k: parts[3].parse()?,
            simd: parts[4].parse()?,
            qf: parts[5].parse()?,
            input_shapes,
        })
    }
}

/// An int16 host tensor (shape + row-major data), the interchange type
/// between the simulator/coordinator and the PJRT executables.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorI16 {
    pub shape: Vec<usize>,
    pub data: Vec<i16>,
}

impl TensorI16 {
    pub fn new(shape: Vec<usize>, data: Vec<i16>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        TensorI16 { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        TensorI16 { shape, data: vec![0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// Byte size (2 bytes/element) — what the DMA/crypto actually move.
    pub fn bytes(&self) -> usize {
        self.data.len() * 2
    }

    /// Serialize to little-endian bytes (for encryption / external storage).
    pub fn to_bytes(&self) -> Vec<u8> {
        self.data.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    /// Deserialize from little-endian bytes.
    pub fn from_bytes(shape: Vec<usize>, bytes: &[u8]) -> Self {
        assert_eq!(bytes.len() % 2, 0);
        let data: Vec<i16> = bytes
            .chunks_exact(2)
            .map(|c| i16::from_le_bytes([c[0], c[1]]))
            .collect();
        TensorI16::new(shape, data)
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let bytes = self.to_bytes();
        xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::S16,
            &self.shape,
            &bytes,
        )
        .map_err(|e| anyhow!("literal creation failed: {e:?}"))
    }
}

/// The PJRT runtime with its artifact registry and compile cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactMeta>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Executions performed (stats).
    pub executions: u64,
}

impl Runtime {
    /// Open the artifact directory (usually `artifacts/`) and parse the
    /// manifest. Artifacts are compiled lazily on first use.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.txt")).with_context(|| {
            format!("reading {}/manifest.txt — run `make artifacts`", dir.display())
        })?;
        let mut manifest = HashMap::new();
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            let meta = ArtifactMeta::parse(line)?;
            manifest.insert(meta.name.clone(), meta);
        }
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: HashMap::new(), executions: 0 })
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.get(name)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.manifest.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    /// Compile (or fetch from cache) an artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let meta = self
            .manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with int16 inputs; returns the int16 outputs
    /// (the lowered computations return a tuple — usually of one tensor).
    pub fn execute(&mut self, name: &str, inputs: &[TensorI16]) -> Result<Vec<TensorI16>> {
        self.compile(name)?;
        let meta = &self.manifest[name];
        if inputs.len() != meta.input_shapes.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.input_shapes.len(),
                inputs.len()
            );
        }
        for (i, (t, s)) in inputs.iter().zip(&meta.input_shapes).enumerate() {
            if &t.shape != s {
                bail!("{name}: input {i} shape {:?} != manifest {:?}", t.shape, s);
            }
        }
        let literals = inputs
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<Vec<_>>>()?;
        let exe = &self.cache[name];
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.executions += 1;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.array_shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
                let data = lit.to_vec::<i16>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                Ok(TensorI16::new(dims, data))
            })
            .collect()
    }
}

/// Locate the artifact directory relative to the crate root (tests,
/// examples and the CLI all use this).
pub fn default_artifact_dir() -> PathBuf {
    let mut p = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    p.push("artifacts");
    p
}

/// Stand-in for the `xla` bindings in offline builds (no `pjrt` feature):
/// the same types and signatures the runtime uses, all failing at
/// [`pjrt_stub::PjRtClient::cpu`] so [`Runtime::open`] reports the missing
/// feature instead of the build breaking on an unavailable native library.
#[cfg(not(feature = "pjrt"))]
mod pjrt_stub {
    #[derive(Debug)]
    pub struct Error(pub &'static str);

    impl std::fmt::Display for Error {
        fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
            f.write_str(self.0)
        }
    }

    impl std::error::Error for Error {}

    const NO_PJRT: &str =
        "fulmine was built without the `pjrt` feature; the PJRT runtime is unavailable";

    pub enum ElementType {
        S16,
    }

    pub struct Literal;

    impl Literal {
        pub fn create_from_shape_and_untyped_data(
            _ty: ElementType,
            _shape: &[usize],
            _data: &[u8],
        ) -> Result<Self, Error> {
            Err(Error(NO_PJRT))
        }

        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            Err(Error(NO_PJRT))
        }

        pub fn array_shape(&self) -> Result<ArrayShape, Error> {
            Err(Error(NO_PJRT))
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            Err(Error(NO_PJRT))
        }
    }

    pub struct ArrayShape;

    impl ArrayShape {
        pub fn dims(&self) -> &[i64] {
            &[]
        }
    }

    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<Self, Error> {
            Err(Error(NO_PJRT))
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            Err(Error(NO_PJRT))
        }
    }

    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            Err(Error(NO_PJRT))
        }
    }

    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            Err(Error(NO_PJRT))
        }
    }

    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<Self, Error> {
            Err(Error(NO_PJRT))
        }
    }

    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> Self {
            XlaComputation
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_line_parses() {
        let m = ArtifactMeta::parse(
            "hwce_conv3_w16|hwce_conv3_w16.hlo.txt|hwce_raw|3|1|8|1x4x18x18;8x4x3x3;1x8x16x16",
        )
        .unwrap();
        assert_eq!(m.name, "hwce_conv3_w16");
        assert_eq!(m.k, 3);
        assert_eq!(m.simd, 1);
        assert_eq!(m.input_shapes.len(), 3);
        assert_eq!(m.input_shapes[0], vec![1, 4, 18, 18]);
    }

    #[test]
    fn malformed_manifest_rejected() {
        assert!(ArtifactMeta::parse("only|three|fields").is_err());
    }

    #[test]
    fn tensor_shape_checks() {
        let t = TensorI16::zeros(vec![2, 3]);
        assert_eq!(t.numel(), 6);
        assert_eq!(t.bytes(), 12);
    }

    #[test]
    fn tensor_byte_roundtrip() {
        let t = TensorI16::new(vec![3], vec![-1, 0, 12345]);
        let b = t.to_bytes();
        assert_eq!(TensorI16::from_bytes(vec![3], &b), t);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        TensorI16::new(vec![2, 2], vec![0; 5]);
    }
}
