//! Analytic cycle model for *software* cryptography on the OR10N cores.
//!
//! Writing a full table-based AES in the micro-ISA is possible but the paper
//! already pins the software costs precisely through its published speedup
//! ratios against the (structurally derived) HWCRYPT throughput, so we encode
//! those and cross-check them against the independent Cortex-M3 numbers the
//! paper cites ([5], [66]):
//!
//! * HWCRYPT AES-128-ECB: 0.38 cpb. §III-B: "a 450× speedup compared to a
//!   software implementation on one core" ⇒ SW ECB ≈ 171 cpb. FELICS [5]
//!   reports 1816 cycles/block = 113.5 cpb and SharkSSL 1066 cycles/block =
//!   66.6 cpb on Cortex-M3 — an OpenRISC core without a dedicated crypto ISA
//!   and with a shared I$ lands plausibly in the same decade.
//! * 4-core ECB: 120× ⇒ 45.6 cpb (near-ideal 3.75× parallel speedup).
//! * XTS single core: 495× vs 0.38 cpb ⇒ 188 cpb; 4-core: 287× ⇒ 109 cpb —
//!   only 1.7× from 4 cores because the ⊗2 tweak chain serializes (§III-B:
//!   "XTS encryption cannot be efficiently parallelized in software due to a
//!   data dependency during the tweak computation step").
//! * Software KECCAK-f[400]: ≈2080 cycles per 20-round permutation on a
//!   32-bit core (25 16-bit lanes packed two-per-word; theta+rho+pi+chi ≈ 8
//!   ops/lane/round), i.e. 130 cpb at a 16-byte rate.

/// Software cycles/byte for AES-128-ECB on one core.
pub const SW_AES_ECB_CPB_1CORE: f64 = 0.38 * 450.0; // = 171
/// Software cycles/byte for AES-128-ECB parallelized on 4 cores.
pub const SW_AES_ECB_CPB_4CORE: f64 = 0.38 * 120.0; // = 45.6
/// Software cycles/byte for AES-128-XTS on one core.
pub const SW_AES_XTS_CPB_1CORE: f64 = 0.38 * 495.0; // = 188.1
/// Software cycles/byte for AES-128-XTS on 4 cores (tweak chain serializes).
pub const SW_AES_XTS_CPB_4CORE: f64 = 0.38 * 287.0; // = 109.06
/// Software cycles/byte for KECCAK-f[400] sponge AE (rate 16 B).
pub const SW_KECCAK_CPB_1CORE: f64 = 130.0;

/// Cycles to encrypt/decrypt `bytes` with the given software configuration.
pub fn sw_crypto_cycles(cpb: f64, bytes: usize) -> u64 {
    (cpb * bytes as f64).ceil() as u64
}

/// Effective cpb for SW XTS on `n` cores, modelling the serial tweak chain
/// with Amdahl's law calibrated on the paper's two published points
/// (1 core: 188 cpb, 4 cores: 109 cpb ⇒ serial fraction ≈ 0.55 of the
/// tweak+XEX work).
pub fn sw_xts_cpb(n_cores: usize) -> f64 {
    match n_cores {
        1 => SW_AES_XTS_CPB_1CORE,
        4 => SW_AES_XTS_CPB_4CORE,
        n => {
            // Amdahl interpolation through the two published points.
            let s = amdahl_serial_fraction();
            SW_AES_XTS_CPB_1CORE * (s + (1.0 - s) / n as f64)
        }
    }
}

/// Effective cpb for SW ECB on `n` cores (embarrassingly parallel).
pub fn sw_ecb_cpb(n_cores: usize) -> f64 {
    match n_cores {
        1 => SW_AES_ECB_CPB_1CORE,
        4 => SW_AES_ECB_CPB_4CORE,
        n => SW_AES_ECB_CPB_1CORE * (0.0667 + (1.0 - 0.0667) / n as f64),
    }
}

fn amdahl_serial_fraction() -> f64 {
    // 109 = 188 (s + (1-s)/4)  ⇒  s = (109/188 − 0.25) / 0.75
    (SW_AES_XTS_CPB_4CORE / SW_AES_XTS_CPB_1CORE - 0.25) / 0.75
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_ratio_anchor_points() {
        assert!((SW_AES_ECB_CPB_1CORE - 171.0).abs() < 0.1);
        assert!((SW_AES_XTS_CPB_1CORE - 188.1).abs() < 0.1);
        assert!((sw_xts_cpb(4) - 109.06).abs() < 0.1);
    }

    #[test]
    fn within_decade_of_cortex_m3_baselines() {
        // FELICS: 113.5 cpb; SharkSSL: 66.6 cpb (both Cortex-M3, AES-128-ECB)
        assert!(SW_AES_ECB_CPB_1CORE / 113.5 < 2.0);
        assert!(SW_AES_ECB_CPB_1CORE / 66.6 < 3.0);
    }

    #[test]
    fn xts_parallelizes_poorly() {
        let speedup_4 = sw_xts_cpb(1) / sw_xts_cpb(4);
        assert!(speedup_4 < 2.0, "XTS 4-core speedup {speedup_4} must be small");
        let speedup_ecb = sw_ecb_cpb(1) / sw_ecb_cpb(4);
        assert!(speedup_ecb > 3.0, "ECB speedup {speedup_ecb} must be near-ideal");
    }

    #[test]
    fn amdahl_interpolation_monotone() {
        assert!(sw_xts_cpb(2) < sw_xts_cpb(1));
        assert!(sw_xts_cpb(2) > sw_xts_cpb(4));
    }

    #[test]
    fn cycle_count_scales_with_bytes() {
        let c = sw_crypto_cycles(SW_AES_ECB_CPB_1CORE, 8192);
        // §III-B: 8 kB ECB in HW ≈ 3100 cycles; SW ≈ 450× more
        let hw = 3100.0;
        assert!((c as f64 / hw - 450.0).abs() / 450.0 < 0.02);
    }
}
