//! Software convolution kernels, written against the OR10N-like micro-ISA
//! and executed on the VM ([`crate::isa`]).
//!
//! Three implementations mirror the §III-C ladder:
//!
//! 1. **naive** — scalar 16-bit loads and single-cycle MACs, with the
//!    compiler-inferred features (hardware loops, post-increment addressing)
//!    the paper notes are automatic;
//! 2. **SIMD** — explicit `pv.sdotsp.h` intrinsics processing output pixels
//!    in aligned pairs, with `pv.pack.h` realignment for the odd-offset
//!    window (the packed-weight trick used by the PULP convolution kernels);
//! 3. **multi-core** — rows split across the four cores, run in cycle
//!    lockstep on the shared TCDM so bank conflicts are simulated.
//!
//! All variants produce bit-exact results vs. the HWCE golden model (same
//! fixed-point semantics: i16 pixels/weights, i32 accumulate, rounded
//! normalization by `qf`, saturation).

use crate::cluster::N_CORES;
use crate::isa::asm::{Asm, Cond, Op};
use crate::isa::vm::Machine;

/// A convolution tile job in TCDM.
#[derive(Debug, Clone, Copy)]
pub struct ConvJob {
    /// Input feature-map width and height (i16 elements).
    pub w: usize,
    pub h: usize,
    /// Kernel size: 3 or 5.
    pub k: usize,
    /// Fractional bits for output normalization.
    pub qf: u8,
    /// TCDM byte addresses.
    pub x_base: u32,
    pub w_base: u32,
    pub y_base: u32,
}

impl ConvJob {
    pub fn ow(&self) -> usize {
        self.w - self.k + 1
    }
    pub fn oh(&self) -> usize {
        self.h - self.k + 1
    }
}

// Register conventions shared by the program builders.
const R_ZERO: u8 = 0; // kept at 0 by convention
const R_XROW: u8 = 1; // input row pointer for current output row
const R_Y: u8 = 3; // output pointer
const R_OX: u8 = 4;
const R_OY: u8 = 5;
const R_ACC: u8 = 6;
const R_XP: u8 = 14; // x window pointer
const R_WP: u8 = 15; // weight pointer

/// Naive scalar kernel: per output pixel, k×k (load x, load w, mac) with a
/// hardware loop over rows; ends with rounded normalization, saturation to
/// i16 and store. Rows `[row0, row1)` of the output are computed (for
/// multi-core splits).
pub fn conv_naive_prog(job: ConvJob, row0: usize, row1: usize) -> Vec<Op> {
    let k = job.k;
    let w_bytes = (job.w * 2) as i32;
    let mut a = Asm::new();
    a.op(Op::Li(R_ZERO, 0));
    a.op(Op::Li(R_OY, row0 as i32));
    a.op(Op::Li(2, row1 as i32));
    a.op(Op::Li(R_XROW, job.x_base as i32 + row0 as i32 * w_bytes));
    a.op(Op::Li(R_Y, job.y_base as i32 + (row0 * job.ow() * 2) as i32));
    a.label("oy_loop");
    {
        a.op(Op::Li(R_OX, 0));
        a.op(Op::Li(7, job.ow() as i32));
        a.label("ox_loop");
        {
            a.op(Op::Li(R_ACC, 0));
            // x window pointer = row ptr + 2*ox
            a.op(Op::Add(R_XP, R_XROW, R_OX));
            a.op(Op::Add(R_XP, R_XP, R_OX));
            a.op(Op::Li(R_WP, job.w_base as i32));
            // hardware loop over kernel rows; kx unrolled (compiler would)
            a.hw_loop_i(k as u32);
            {
                for kx in 0..k {
                    a.op(Op::Lh { rd: 8, ra: R_XP, off: (kx * 2) as i32, post: 0 });
                    a.op(Op::Lh { rd: 9, ra: R_WP, off: 0, post: 2 });
                    a.op(Op::Mac(R_ACC, 8, 9));
                }
                a.op(Op::Addi(R_XP, R_XP, w_bytes));
            }
            a.end_loop();
            // normalize, saturate, store
            a.op(Op::AddNr(R_ACC, R_ACC, job.qf));
            a.op(Op::Clip(R_ACC, R_ACC, 16));
            a.op(Op::Sh { rs: R_ACC, ra: R_Y, off: 0, post: 2 });
            a.op(Op::Addi(R_OX, R_OX, 1));
            a.branch(Cond::Lt, R_OX, 7, "ox_loop");
        }
        a.op(Op::Addi(R_XROW, R_XROW, w_bytes));
        a.op(Op::Addi(R_OY, R_OY, 1));
        a.branch(Cond::Lt, R_OY, 2, "oy_loop");
    }
    a.op(Op::Halt);
    a.finish()
}

/// Pack the k×k i16 weights into the even-pair SIMD layout used by
/// [`conv_simd_prog`]: per kernel row, ceil(k/2) 32-bit words
/// `[w0,w1][w2,w3][w4,0]` (lane 0 = lower element). Returns words.
pub fn pack_weights_simd(k: usize, weights: &[i16]) -> Vec<u32> {
    assert_eq!(weights.len(), k * k);
    let wpr = k.div_ceil(2);
    let mut out = Vec::with_capacity(k * wpr);
    for row in 0..k {
        for i in 0..wpr {
            let lo = weights[row * k + 2 * i] as u16 as u32;
            let hi = if 2 * i + 1 < k { weights[row * k + 2 * i + 1] as u16 as u32 } else { 0 };
            out.push(lo | (hi << 16));
        }
    }
    out
}

/// SIMD kernel (5×5 only): processes output pixels in pairs (even `ox`
/// aligned for 32-bit loads; the odd pixel's windows are realigned with
/// `pv.pack.h`). Packed weights are preloaded into registers r16..r30 once
/// per tile. Requires even `ow`.
pub fn conv5x5_simd_prog(job: ConvJob, row0: usize, row1: usize) -> Vec<Op> {
    assert_eq!(job.k, 5);
    assert!(job.ow() % 2 == 0, "SIMD kernel requires even output width");
    assert!(job.w % 2 == 0, "SIMD kernel requires even (word-aligned) rows");
    assert!(job.x_base % 4 == 0);
    let w_bytes = (job.w * 2) as i32;
    let mut a = Asm::new();
    a.op(Op::Li(R_ZERO, 0));
    // Preload 15 packed weight words into r16..r30.
    a.op(Op::Li(R_WP, job.w_base as i32));
    for i in 0..15u8 {
        a.op(Op::Lw { rd: 16 + i, ra: R_WP, off: 0, post: 4 });
    }
    a.op(Op::Li(R_OY, row0 as i32));
    a.op(Op::Li(2, row1 as i32));
    a.op(Op::Li(R_XROW, job.x_base as i32 + row0 as i32 * w_bytes));
    a.op(Op::Li(R_Y, job.y_base as i32 + (row0 * job.ow() * 2) as i32));
    a.label("oy_loop");
    {
        a.op(Op::Li(R_OX, 0));
        a.op(Op::Li(7, job.ow() as i32));
        a.label("ox_loop");
        {
            a.op(Op::Li(R_ACC, 0)); // even accumulator
            a.op(Op::Li(13, 0)); // odd accumulator
            a.op(Op::Add(R_XP, R_XROW, R_OX));
            a.op(Op::Add(R_XP, R_XP, R_OX));
            // 5 rows unrolled; weight regs r16+3*row..r16+3*row+2
            for row in 0..5u8 {
                let wr = 16 + 3 * row;
                // x words: r8=[x0,x1] r9=[x2,x3] r10=[x4,x5]; r11=x6 (scalar)
                a.op(Op::Lw { rd: 8, ra: R_XP, off: 0, post: 0 });
                a.op(Op::Lw { rd: 9, ra: R_XP, off: 4, post: 0 });
                a.op(Op::Lw { rd: 10, ra: R_XP, off: 8, post: 0 });
                a.op(Op::Lh { rd: 11, ra: R_XP, off: 12, post: w_bytes });
                // even pixel: dot with [w0w1][w2w3][w4,0]
                a.op(Op::SdotpH(R_ACC, 8, wr));
                a.op(Op::SdotpH(R_ACC, 9, wr + 1));
                a.op(Op::SdotpH(R_ACC, 10, wr + 2));
                // odd pixel: realign windows [x1x2][x3x4][x5x6]
                a.op(Op::PackH(12, 8, 9));
                a.op(Op::SdotpH(13, 12, wr));
                a.op(Op::PackH(12, 9, 10));
                a.op(Op::SdotpH(13, 12, wr + 1));
                a.op(Op::PackH(12, 10, 11));
                a.op(Op::SdotpH(13, 12, wr + 2));
            }
            // stores: even then odd
            a.op(Op::AddNr(R_ACC, R_ACC, job.qf));
            a.op(Op::Clip(R_ACC, R_ACC, 16));
            a.op(Op::Sh { rs: R_ACC, ra: R_Y, off: 0, post: 2 });
            a.op(Op::AddNr(13, 13, job.qf));
            a.op(Op::Clip(13, 13, 16));
            a.op(Op::Sh { rs: 13, ra: R_Y, off: 0, post: 2 });
            a.op(Op::Addi(R_OX, R_OX, 2));
            a.branch(Cond::Lt, R_OX, 7, "ox_loop");
        }
        a.op(Op::Addi(R_XROW, R_XROW, w_bytes));
        a.op(Op::Addi(R_OY, R_OY, 1));
        a.branch(Cond::Lt, R_OY, 2, "oy_loop");
    }
    a.op(Op::Halt);
    a.finish()
}

/// Convolution implementation selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConvImpl {
    Naive,
    Simd,
}

/// Run a convolution tile on `n_cores` cores (output rows split evenly) and
/// return `(cycles, cycles_per_output_pixel)`. The machine's TCDM must
/// already hold x and weights (packed layout for SIMD).
pub fn run_conv(m: &mut Machine, job: ConvJob, imp: ConvImpl, n_cores: usize) -> (u64, f64) {
    assert!(n_cores >= 1 && n_cores <= N_CORES);
    let oh = job.oh();
    let rows_per = oh.div_ceil(n_cores);
    for c in 0..n_cores {
        let row0 = c * rows_per;
        let row1 = ((c + 1) * rows_per).min(oh);
        if row0 >= row1 {
            continue;
        }
        let prog = match imp {
            ConvImpl::Naive => conv_naive_prog(job, row0, row1),
            ConvImpl::Simd => conv5x5_simd_prog(job, row0, row1),
        };
        m.load_program(c, prog, &[]);
    }
    let r = m.run(500_000_000);
    let px = (job.ow() * oh) as f64;
    (r.cycles, r.cycles as f64 / px)
}

/// Host-side helper: write a tile's inputs into TCDM. `weights` is in
/// row-major i16; packed layout is used automatically for SIMD.
pub fn stage_tile(m: &mut Machine, job: ConvJob, x: &[i16], weights: &[i16], imp: ConvImpl) {
    assert_eq!(x.len(), job.w * job.h);
    assert_eq!(weights.len(), job.k * job.k);
    for (i, &v) in x.iter().enumerate() {
        m.tcdm.write_u16(job.x_base + 2 * i as u32, v as u16);
    }
    match imp {
        ConvImpl::Naive => {
            for (i, &v) in weights.iter().enumerate() {
                m.tcdm.write_u16(job.w_base + 2 * i as u32, v as u16);
            }
        }
        ConvImpl::Simd => {
            for (i, w) in pack_weights_simd(job.k, weights).iter().enumerate() {
                m.tcdm.write_u32(job.w_base + 4 * i as u32, *w);
            }
        }
    }
}

/// Read back the output tile.
pub fn read_output(m: &Machine, job: ConvJob) -> Vec<i16> {
    (0..job.ow() * job.oh())
        .map(|i| m.tcdm.read_u16(job.y_base + 2 * i as u32) as i16)
        .collect()
}

/// Reference convolution with HWCE fixed-point semantics (i32 accumulate,
/// rounded normalization, i16 saturation) — used for validating the VM
/// kernels; the authoritative golden model lives in [`crate::hwce`].
pub fn conv_ref(job: ConvJob, x: &[i16], weights: &[i16]) -> Vec<i16> {
    let (k, w) = (job.k, job.w);
    let mut out = Vec::with_capacity(job.ow() * job.oh());
    for oy in 0..job.oh() {
        for ox in 0..job.ow() {
            let mut acc: i64 = 0;
            for ky in 0..k {
                for kx in 0..k {
                    acc += x[(oy + ky) * w + ox + kx] as i64 * weights[ky * k + kx] as i64;
                }
            }
            out.push(crate::fixedpoint::writeback(acc, job.qf));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_data(n: usize, seed: u64) -> Vec<i16> {
        let mut x = seed;
        (0..n)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                (x % 512) as i16 - 256
            })
            .collect()
    }

    fn job5() -> ConvJob {
        ConvJob { w: 20, h: 12, k: 5, qf: 8, x_base: 0, w_base: 0x8000, y_base: 0x9000 }
    }

    #[test]
    fn naive_5x5_matches_reference() {
        let job = job5();
        let x = test_data(job.w * job.h, 1);
        let wts = test_data(25, 2);
        let mut m = Machine::new();
        stage_tile(&mut m, job, &x, &wts, ConvImpl::Naive);
        let (_, cpp) = run_conv(&mut m, job, ConvImpl::Naive, 1);
        assert_eq!(read_output(&m, job), conv_ref(job, &x, &wts));
        // §III-C: naive single core ≈ 94 cycles/px
        assert!(cpp > 80.0 && cpp < 110.0, "naive cycles/px = {cpp}");
    }

    #[test]
    fn simd_5x5_matches_reference() {
        let job = job5();
        let x = test_data(job.w * job.h, 3);
        let wts = test_data(25, 4);
        let mut m = Machine::new();
        stage_tile(&mut m, job, &x, &wts, ConvImpl::Simd);
        let (_, cpp) = run_conv(&mut m, job, ConvImpl::Simd, 1);
        assert_eq!(read_output(&m, job), conv_ref(job, &x, &wts));
        assert!(cpp < 50.0, "simd cycles/px = {cpp}");
    }

    #[test]
    fn naive_3x3_matches_reference() {
        let job = ConvJob { w: 18, h: 10, k: 3, qf: 6, x_base: 0, w_base: 0x8000, y_base: 0x9000 };
        let x = test_data(job.w * job.h, 5);
        let wts = test_data(9, 6);
        let mut m = Machine::new();
        stage_tile(&mut m, job, &x, &wts, ConvImpl::Naive);
        run_conv(&mut m, job, ConvImpl::Naive, 1);
        assert_eq!(read_output(&m, job), conv_ref(job, &x, &wts));
    }

    #[test]
    fn four_core_matches_and_speeds_up() {
        let job = job5();
        let x = test_data(job.w * job.h, 7);
        let wts = test_data(25, 8);

        let mut m1 = Machine::new();
        stage_tile(&mut m1, job, &x, &wts, ConvImpl::Naive);
        let (c1, _) = run_conv(&mut m1, job, ConvImpl::Naive, 1);

        let mut m4 = Machine::new();
        stage_tile(&mut m4, job, &x, &wts, ConvImpl::Naive);
        let (c4, cpp4) = run_conv(&mut m4, job, ConvImpl::Naive, 4);
        assert_eq!(read_output(&m4, job), conv_ref(job, &x, &wts));
        let speedup = c1 as f64 / c4 as f64;
        // §III-C: "almost ideal speedup" 94 → 24 cycles/px
        assert!(speedup > 3.0, "4-core speedup {speedup}");
        assert!(cpp4 < 32.0, "4-core cycles/px = {cpp4}");
    }

    #[test]
    fn simd_multicore_reaches_paper_band() {
        let job = ConvJob { w: 36, h: 36, k: 5, qf: 8, x_base: 0, w_base: 0x8000, y_base: 0x9000 };
        let x = test_data(job.w * job.h, 9);
        let wts = test_data(25, 10);
        let mut m = Machine::new();
        stage_tile(&mut m, job, &x, &wts, ConvImpl::Simd);
        let (_, cpp) = run_conv(&mut m, job, ConvImpl::Simd, 4);
        assert_eq!(read_output(&m, job), conv_ref(job, &x, &wts));
        // §III-C: optimized multi-core ≈ 13 cycles/px on average
        assert!(cpp > 6.0 && cpp < 18.0, "4-core SIMD cycles/px = {cpp}");
    }

    #[test]
    fn saturation_path_exercised() {
        let job = ConvJob { w: 9, h: 9, k: 5, qf: 0, x_base: 0, w_base: 0x8000, y_base: 0x9000 };
        let x = vec![i16::MAX; job.w * job.h];
        let wts = vec![i16::MAX; 25];
        let mut m = Machine::new();
        stage_tile(&mut m, job, &x, &wts, ConvImpl::Naive);
        run_conv(&mut m, job, ConvImpl::Naive, 1);
        let out = read_output(&m, job);
        assert!(out.iter().all(|&v| v == i16::MAX));
        assert_eq!(out, conv_ref(job, &x, &wts));
    }

    #[test]
    fn weight_packing_layout() {
        let w: Vec<i16> = (1..=25).collect();
        let packed = pack_weights_simd(5, &w);
        assert_eq!(packed.len(), 15);
        assert_eq!(packed[0], 1 | (2 << 16));
        assert_eq!(packed[2], 5); // [w4, 0]
        assert_eq!(packed[3], 6 | (7 << 16)); // second row starts
    }
}
