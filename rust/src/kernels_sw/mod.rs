//! Software kernels for the OR10N-like cores (§III-B/§III-C baselines).
//!
//! * [`conv`] — 5×5 and 3×3 convolutions, naive scalar and SIMD-optimized,
//!   single- and multi-core, *executed on the VM* so cycle counts (the
//!   94 / 24 / 13 cycles-per-pixel ladder of §III-C) come out of the
//!   simulation rather than being asserted.
//! * [`dsp`] — ReLU, 2×2 max pooling and dense (fully-connected) kernels
//!   used by the CNN pipelines for the parts the paper runs in software.
//! * [`crypto_cost`] — analytic cycle model for *software* AES-128-ECB/XTS
//!   and KECCAK-f[400], derived from the paper's published speedup ratios
//!   and cross-checked against the FELICS/SharkSSL Cortex-M3 figures it
//!   cites; the functional result always comes from [`crate::crypto`].
//! * [`eeg_cost`] — operation-count-based cycle model for the seizure
//!   detection pipeline (PCA, DWT, energy coefficients, SVM) of §IV-C,
//!   with the paper's parallel-fraction structure (PCA diagonalization is
//!   serial, the rest parallelizes).

pub mod conv;
pub mod crypto_cost;
pub mod dsp;
pub mod eeg_cost;
