//! Cycle model for the EEG seizure-detection pipeline of §IV-C
//! ([30], [34]): PCA over a 23-channel × 256-sample window → 9 principal
//! components → digital wavelet transform → energy coefficients → SVM
//! classification.
//!
//! The functional computation is implemented in [`crate::apps::eeg`] (rust,
//! fixed point); this module provides the *cycle* model from operation
//! counts at the measured per-op throughput of the VM kernels, with the
//! parallel-fraction structure the paper reports: "several components of
//! PCA, like diagonalization, are not amenable to parallelization.
//! Nonetheless, we observe a 2.6× speedup with four cores excluding AES".

/// EEG window parameters (§IV-C).
pub const N_CHANNELS: usize = 23;
pub const N_SAMPLES: usize = 256;
pub const N_COMPONENTS: usize = 9;
/// DWT decomposition levels used for the energy coefficients.
pub const DWT_LEVELS: usize = 4;

/// Operation counts (MAC-dominated, counted as OpenRISC-equivalent ops).
pub struct EegOpCounts {
    /// Covariance matrix accumulation: ch² × samples MACs (symmetric half).
    pub covariance: u64,
    /// Jacobi eigendecomposition of the 23×23 covariance (serial).
    pub diagonalization: u64,
    /// Projection of samples onto 9 components: ch × comp × samples.
    pub projection: u64,
    /// DWT: 4-tap filters over 9 components × samples, all levels ≈ 2n.
    pub dwt: u64,
    /// Energy coefficients + SVM dot products.
    pub svm: u64,
}

impl EegOpCounts {
    pub fn standard() -> Self {
        let ch = N_CHANNELS as u64;
        let n = N_SAMPLES as u64;
        let comp = N_COMPONENTS as u64;
        EegOpCounts {
            covariance: ch * (ch + 1) / 2 * n,
            // Jacobi sweeps: ~6 sweeps × 4·ch³/... use 8·ch³ rotations cost
            diagonalization: 8 * ch * ch * ch,
            projection: ch * comp * n,
            dwt: 2 * comp * n * 4 * 2, // 4-tap lo+hi filters, geometric levels ≈ 2n
            svm: comp * (DWT_LEVELS as u64 + 1) * 64, // features × support-vector dim
        }
    }

    pub fn total(&self) -> u64 {
        self.covariance + self.diagonalization + self.projection + self.dwt + self.svm
    }

    /// Serial ops: the Jacobi rotation search + angle computation (~1/4 of
    /// the diagonalization work; the row/column updates parallelize) and the
    /// final SVM reduction.
    pub fn serial(&self) -> u64 {
        self.diagonalization / 4 + self.svm
    }
}

/// Cycles per MAC-equivalent op in optimized software (SIMD dot products
/// where the data layout allows, scalar in the Jacobi rotations) — measured
/// from the VM dense/conv kernels: between [`crate::kernels_sw::dsp::DENSE_CYC_PER_MAC`]
/// and scalar ~3 cycles/op.
pub const CYC_PER_OP_PARALLEL: f64 = 1.8;
pub const CYC_PER_OP_SERIAL: f64 = 3.0;

/// Cycles for the seizure-detection pipeline (excluding encryption) on
/// `n_cores` cores.
pub fn eeg_pipeline_cycles(n_cores: usize) -> u64 {
    let ops = EegOpCounts::standard();
    let parallel = (ops.total() - ops.serial()) as f64 * CYC_PER_OP_PARALLEL;
    let serial = ops.serial() as f64 * CYC_PER_OP_SERIAL;
    (serial + parallel / n_cores as f64).ceil() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_core_speedup_matches_paper_band() {
        // §IV-C: "a 2.6× speedup with four cores excluding AES"
        let s = eeg_pipeline_cycles(1) as f64 / eeg_pipeline_cycles(4) as f64;
        assert!(s > 2.2 && s < 3.0, "EEG 4-core speedup {s}");
    }

    #[test]
    fn op_counts_sane() {
        let ops = EegOpCounts::standard();
        // total workload must be well under a second at 85 MHz (0.5 s budget)
        let t = eeg_pipeline_cycles(4) as f64 / 85e6;
        assert!(t < 0.1, "pipeline time {t} s");
        assert!(ops.total() > 100_000);
    }

    #[test]
    fn serial_fraction_dominated_by_diagonalization() {
        let ops = EegOpCounts::standard();
        assert!(ops.diagonalization > ops.svm);
        assert!(ops.serial() < ops.total() / 2);
    }
}
