//! Software DSP kernels for the CNN pipeline stages the paper runs on the
//! cores: ReLU activation, 2×2 max pooling, and dense (fully-connected)
//! layers with the SIMD dot-product extension.

use crate::isa::asm::{Asm, Op};

/// ReLU over `n` i16 elements at `base` (in place): per element
/// load/relu/store inside a hardware loop — 3 cycles/element.
pub fn relu_prog(base: u32, n: usize) -> Vec<Op> {
    let mut a = Asm::new();
    a.op(Op::Li(1, base as i32));
    a.hw_loop_i(n as u32);
    a.op(Op::Lh { rd: 2, ra: 1, off: 0, post: 0 });
    a.op(Op::Relu(2, 2));
    a.op(Op::Sh { rs: 2, ra: 1, off: 0, post: 2 });
    a.end_loop();
    a.op(Op::Halt);
    a.finish()
}

/// 2×2 max pooling with stride 2: input `w`×`h` i16 at `x_base`, output
/// (w/2)×(h/2) at `y_base`.
pub fn maxpool2x2_prog(x_base: u32, y_base: u32, w: usize, h: usize) -> Vec<Op> {
    assert!(w % 2 == 0 && h % 2 == 0);
    let w_b = (w * 2) as i32;
    let mut a = Asm::new();
    a.op(Op::Li(3, y_base as i32));
    for oy in 0..h / 2 {
        a.op(Op::Li(1, x_base as i32 + (2 * oy) as i32 * w_b));
        a.hw_loop_i((w / 2) as u32);
        a.op(Op::Lh { rd: 4, ra: 1, off: 0, post: 0 });
        a.op(Op::Lh { rd: 5, ra: 1, off: 2, post: 0 });
        a.op(Op::Lh { rd: 6, ra: 1, off: w_b, post: 0 });
        a.op(Op::Lh { rd: 7, ra: 1, off: w_b + 2, post: 4 });
        a.op(Op::Max(4, 4, 5));
        a.op(Op::Max(6, 6, 7));
        a.op(Op::Max(4, 4, 6));
        a.op(Op::Sh { rs: 4, ra: 3, off: 0, post: 2 });
        a.end_loop();
    }
    a.op(Op::Halt);
    a.finish()
}

/// Dense (fully-connected) row: y[j] = clip(norm(Σ_i x[i]·W[j,i])) for one
/// output neuron, SIMD dot product over pairs — ~1.5 cycles per input
/// element. `n` must be even; x and the weight row are contiguous i16.
pub fn dense_row_prog(x_base: u32, w_base: u32, y_addr: u32, n: usize, qf: u8) -> Vec<Op> {
    assert!(n % 2 == 0 && x_base % 4 == 0 && w_base % 4 == 0);
    let mut a = Asm::new();
    a.op(Op::Li(1, x_base as i32));
    a.op(Op::Li(2, w_base as i32));
    a.op(Op::Li(3, 0));
    a.hw_loop_i((n / 2) as u32);
    a.op(Op::Lw { rd: 4, ra: 1, off: 0, post: 4 });
    a.op(Op::Lw { rd: 5, ra: 2, off: 0, post: 4 });
    a.op(Op::SdotpH(3, 4, 5));
    a.end_loop();
    a.op(Op::AddNr(3, 3, qf));
    a.op(Op::Clip(3, 3, 16));
    a.op(Op::Li(6, y_addr as i32));
    a.op(Op::Sh { rs: 3, ra: 6, off: 0, post: 0 });
    a.op(Op::Halt);
    a.finish()
}

/// Measured software costs (cycles/element) for the DSP kernels, used by the
/// analytic pipeline models. Derived by execution in the tests below.
pub const RELU_CYC_PER_ELEM: f64 = 3.0;
pub const MAXPOOL_CYC_PER_OUT: f64 = 8.0;
pub const DENSE_CYC_PER_MAC: f64 = 1.6;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::vm::Machine;

    #[test]
    fn relu_functional_and_cost() {
        let mut m = Machine::new();
        let vals: Vec<i16> = vec![-5, 3, -1, 0, 7, -32768, 32767, -2];
        for (i, &v) in vals.iter().enumerate() {
            m.tcdm.write_u16((i * 2) as u32, v as u16);
        }
        m.load_program(0, relu_prog(0, vals.len()), &[]);
        let r = m.run(10_000);
        for (i, &v) in vals.iter().enumerate() {
            assert_eq!(m.tcdm.read_u16((i * 2) as u32) as i16, v.max(0));
        }
        let cpe = r.cycles as f64 / vals.len() as f64;
        assert!(cpe < RELU_CYC_PER_ELEM + 1.5, "relu cycles/elem {cpe}");
    }

    #[test]
    fn maxpool_functional() {
        let mut m = Machine::new();
        let (w, h) = (4usize, 4usize);
        let x: Vec<i16> = vec![1, 5, 2, 0, 3, 4, -1, 9, 0, 0, 7, 7, -2, 1, 6, 8];
        for (i, &v) in x.iter().enumerate() {
            m.tcdm.write_u16((i * 2) as u32, v as u16);
        }
        m.load_program(0, maxpool2x2_prog(0, 0x100, w, h), &[]);
        m.run(10_000);
        let out: Vec<i16> = (0..4).map(|i| m.tcdm.read_u16(0x100 + 2 * i) as i16).collect();
        assert_eq!(out, vec![5, 9, 1, 8]);
    }

    #[test]
    fn dense_row_functional_and_cost() {
        let mut m = Machine::new();
        let n = 64usize;
        let x: Vec<i16> = (0..n as i16).collect();
        let w: Vec<i16> = (0..n as i16).map(|i| 1 - (i % 3)).collect();
        for (i, &v) in x.iter().enumerate() {
            m.tcdm.write_u16((i * 2) as u32, v as u16);
        }
        for (i, &v) in w.iter().enumerate() {
            m.tcdm.write_u16(0x1000 + (i * 2) as u32, v as u16);
        }
        m.load_program(0, dense_row_prog(0, 0x1000, 0x2000, n, 0), &[]);
        let r = m.run(10_000);
        let expect: i64 = x.iter().zip(&w).map(|(&a, &b)| a as i64 * b as i64).sum();
        assert_eq!(m.tcdm.read_u16(0x2000) as i16, crate::fixedpoint::writeback(expect, 0));
        let cpm = r.cycles as f64 / n as f64;
        assert!(cpm < DENSE_CYC_PER_MAC + 0.4, "dense cycles/mac {cpm}");
    }
}
