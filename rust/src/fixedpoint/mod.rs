//! Fixed-point arithmetic exactly as implemented by the HWCE datapath and the
//! OR10N fixed-point ISA extensions (§II of the paper).
//!
//! Pixels (feature-map activations) are Q-format 16-bit signed values with a
//! run-time-configurable number of fractional bits `qf`. Weights are 16, 8 or
//! 4-bit signed values sharing the same fractional interpretation. Products
//! are accumulated in 32 bits; before write-back the accumulator is
//! *normalized* (arithmetic shift right by `qf` with round-to-nearest) and
//! *saturated* to the i16 range — mirroring the "fractional part
//! normalization and saturation" stage of the HWCE second-stage reduction
//! tree (Fig. 5) and the core's `addN/mulN/clip` extensions.
//!
//! All three convolution implementations in this repo (rust golden model,
//! pure-jnp oracle, Pallas kernel) follow these exact semantics, so results
//! are bit-exact across layers.

/// A Q-format descriptor: 16-bit signed container with `frac` fractional bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QFormat {
    /// Number of fractional bits (0..=15).
    pub frac: u8,
}

impl QFormat {
    pub const fn new(frac: u8) -> Self {
        assert!(frac <= 15);
        QFormat { frac }
    }

    /// Quantize an `f32` to this Q-format (round-to-nearest, saturating).
    pub fn from_f32(self, v: f32) -> i16 {
        let scaled = (v * (1i32 << self.frac) as f32).round();
        sat16(scaled as i64)
    }

    /// Convert a fixed-point value back to `f32`.
    pub fn to_f32(self, v: i16) -> f32 {
        v as f32 / (1i32 << self.frac) as f32
    }
}

/// Saturate a wide value to the i16 range (HWCE write-back saturation).
#[inline]
pub fn sat16(v: i64) -> i16 {
    v.clamp(i16::MIN as i64, i16::MAX as i64) as i16
}

/// Saturate to the i8 range (8-bit weight quantization).
#[inline]
pub fn sat8(v: i64) -> i8 {
    v.clamp(i8::MIN as i64, i8::MAX as i64) as i8
}

/// Saturate to the signed 4-bit range [-8, 7] (4-bit weight quantization).
#[inline]
pub fn sat4(v: i64) -> i8 {
    v.clamp(-8, 7) as i8
}

/// Normalize a 32-bit accumulator by `frac` bits with round-to-nearest
/// (adding half an LSB before the arithmetic shift), as the HWCE
/// normalization stage and the OR10N `mulN.r` instruction do.
#[inline]
pub fn norm_round(acc: i64, frac: u8) -> i64 {
    if frac == 0 {
        acc
    } else {
        (acc + (1i64 << (frac - 1))) >> frac
    }
}

/// Full HWCE write-back: normalize then saturate to 16 bits.
#[inline]
pub fn writeback(acc: i64, frac: u8) -> i16 {
    sat16(norm_round(acc, frac))
}

/// Saturating fixed-point addition (OR10N `add` + `clip` fusion).
#[inline]
pub fn add_sat(a: i16, b: i16) -> i16 {
    sat16(a as i64 + b as i64)
}

/// Fixed-point multiply with normalization and rounding
/// (OR10N `mulN.r` single-cycle instruction).
#[inline]
pub fn mul_norm(a: i16, b: i16, frac: u8) -> i16 {
    writeback(a as i64 * b as i64, frac)
}

/// Clip to a symmetric power-of-two range (OR10N `clip` instruction).
#[inline]
pub fn clip(v: i32, bits: u8) -> i32 {
    debug_assert!(bits >= 1 && bits <= 31);
    let hi = (1i32 << (bits - 1)) - 1;
    let lo = -(1i32 << (bits - 1));
    v.clamp(lo, hi)
}

/// Quantize an f32 slice into Q-format i16s.
pub fn quantize_vec(q: QFormat, v: &[f32]) -> Vec<i16> {
    v.iter().map(|&x| q.from_f32(x)).collect()
}

/// Dequantize an i16 slice.
pub fn dequantize_vec(q: QFormat, v: &[i16]) -> Vec<f32> {
    v.iter().map(|&x| q.to_f32(x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qformat_roundtrip_exact_values() {
        let q = QFormat::new(8);
        for v in [-1.0f32, -0.5, 0.0, 0.25, 1.5, 100.0] {
            let fx = q.from_f32(v);
            assert_eq!(q.to_f32(fx), v, "value {v} should be exact in Q8.8");
        }
    }

    #[test]
    fn qformat_saturates() {
        let q = QFormat::new(8);
        assert_eq!(q.from_f32(1e9), i16::MAX);
        assert_eq!(q.from_f32(-1e9), i16::MIN);
    }

    #[test]
    fn norm_round_rounds_to_nearest() {
        // 3/2 rounds to 2 (round-half-up on positives)
        assert_eq!(norm_round(3, 1), 2);
        assert_eq!(norm_round(2, 1), 1);
        assert_eq!(norm_round(1, 1), 1);
        assert_eq!(norm_round(-1, 1), 0); // (-1 + 1) >> 1
        assert_eq!(norm_round(-3, 1), -1);
        assert_eq!(norm_round(5, 0), 5);
    }

    #[test]
    fn writeback_saturates_both_ends() {
        assert_eq!(writeback(i64::from(i16::MAX) << 4, 0), i16::MAX);
        assert_eq!(writeback((i64::from(i16::MAX) + 10) << 4, 4), i16::MAX);
        assert_eq!(writeback((i64::from(i16::MIN) - 10) << 4, 4), i16::MIN);
    }

    #[test]
    fn mul_norm_matches_float_within_lsb() {
        let q = QFormat::new(10);
        let a = q.from_f32(1.25);
        let b = q.from_f32(-2.5);
        let r = mul_norm(a, b, q.frac);
        assert!((q.to_f32(r) - (-3.125)).abs() < 1.0 / 1024.0);
    }

    #[test]
    fn clip_bounds() {
        assert_eq!(clip(1000, 8), 127);
        assert_eq!(clip(-1000, 8), -128);
        assert_eq!(clip(5, 8), 5);
        assert_eq!(clip(7, 4), 7);
        assert_eq!(clip(8, 4), 7);
        assert_eq!(clip(-9, 4), -8);
    }

    #[test]
    fn sat4_range() {
        assert_eq!(sat4(100), 7);
        assert_eq!(sat4(-100), -8);
        assert_eq!(sat4(-8), -8);
        assert_eq!(sat4(7), 7);
    }

    #[test]
    fn add_sat_saturates() {
        assert_eq!(add_sat(i16::MAX, 1), i16::MAX);
        assert_eq!(add_sat(i16::MIN, -1), i16::MIN);
        assert_eq!(add_sat(100, 23), 123);
    }
}
