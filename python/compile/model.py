"""L2: quantized CNN graphs built on the L1 HWCE Pallas kernel.

Everything is int16 fixed point (Q-format with ``qf`` fractional bits),
composed exclusively from the HWCE kernel plus the elementwise/reduction
helpers whose semantics the rust side mirrors exactly:

* ``conv_layer``   — HWCE multi-channel conv + optional stride (computed
  densely and subsampled, as the HWCE has no native stride) + saturating
  bias + ReLU + optional 2x2 max pooling;
* ``resnet20``     — the CIFAR-style ResNet-20 of He et al. [10] used by the
  secure-surveillance use case (§IV-A), with option-A (zero-padded identity)
  shortcuts so every convolution is a native HWCE 3x3;
* ``facedet_12net`` / ``facedet_24net`` — the first two stages of the Li et
  al. [29] face-detection cascade used by §IV-B, batched over windows;
* ``quickstart_conv`` — a small single-layer graph for the quickstart
  example and smoke tests.

The AOT driver (``aot.py``) lowers each entry of ``ARTIFACTS`` to HLO text.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels.hwce import hwce_layer, relu_i16, sat_add_i16

QF = 8  # Q8.8 fixed point everywhere


def pad_same(x, k: int):
    """Zero-pad H/W for 'same' valid convolution (the DMA writes zero
    borders when staging tiles on the silicon; in the AOT graph the pad is
    part of the HLO)."""
    p = (k - 1) // 2
    return jnp.pad(x, ((0, 0), (0, 0), (p, p), (p, p)))


def maxpool2x2(x):
    b, c, h, w = x.shape
    x = x.reshape(b, c, h // 2, 2, w // 2, 2)
    return x.max(axis=(3, 5))


def avgpool_all(x, qf_shift: int):
    """Global average pool with fixed-point rounding: sum >> log2(n)."""
    b, c, h, w = x.shape
    s = x.astype(jnp.int64).sum(axis=(2, 3))
    half = jnp.int64(1 << (qf_shift - 1))
    return jnp.clip((s + half) >> qf_shift, -32768, 32767).astype(jnp.int16)


def dense_i16(x, w, b, qf: int = QF, relu: bool = True):
    """Fixed-point dense layer: sat16(round((x @ w.T) >> qf) + b).

    x (B, N) i16, w (M, N) i16, b (M) i16.
    """
    acc = jnp.matmul(x.astype(jnp.int64), w.astype(jnp.int64).T)
    half = jnp.int64(1 << (qf - 1)) if qf > 0 else jnp.int64(0)
    y = (acc + half) >> qf if qf > 0 else acc
    y = jnp.clip(y + b.astype(jnp.int64)[None, :], -32768, 32767).astype(jnp.int16)
    return relu_i16(y) if relu else y


def conv_layer(x, w, bias, *, k: int, simd: int, stride: int = 1, relu: bool = True,
               pool: bool = False, same: bool = True, qf: int = QF):
    """One HWCE-mapped convolutional layer."""
    if same:
        x = pad_same(x, k)
    b_, _, h, ww = x.shape
    cout = w.shape[0]
    oh, ow = h - k + 1, ww - k + 1
    y_in = jnp.zeros((b_, cout, oh, ow), dtype=jnp.int16)
    y = hwce_layer(x, w, y_in, k=k, qf=qf, simd=simd)
    if stride > 1:
        y = y[:, :, ::stride, ::stride]
    y = sat_add_i16(y, bias[None, :, None, None])
    if relu:
        y = relu_i16(y)
    if pool:
        y = maxpool2x2(y)
    return y


# --------------------------------------------------------------------------
# ResNet-20 (CIFAR topology, option-A shortcuts) — §IV-A workload
# --------------------------------------------------------------------------

RESNET20_STAGES = (16, 32, 64)
RESNET20_BLOCKS_PER_STAGE = 3


def resnet20_param_shapes():
    """Ordered (name, shape) list of all parameters (documented contract
    with the rust side, which generates/encrypts/feeds them)."""
    shapes = [("conv1.w", (16, 3, 3, 3)), ("conv1.b", (16,))]
    cin = 16
    for s, cout in enumerate(RESNET20_STAGES):
        for blk in range(RESNET20_BLOCKS_PER_STAGE):
            pre = f"s{s}b{blk}"
            shapes.append((f"{pre}.w1", (cout, cin, 3, 3)))
            shapes.append((f"{pre}.b1", (cout,)))
            shapes.append((f"{pre}.w2", (cout, cout, 3, 3)))
            shapes.append((f"{pre}.b2", (cout,)))
            cin = cout
    shapes.append(("fc.w", (10, 64)))
    shapes.append(("fc.b", (10,)))
    return shapes


def resnet20(x, *params, simd: int = 4):
    """ResNet-20 forward. x (B, 3, 32, 32) i16; params flat in
    ``resnet20_param_shapes`` order; returns (B, 10) i16 logits."""
    it = iter(params)
    nxt = lambda: next(it)

    y = conv_layer(x, nxt(), nxt(), k=3, simd=simd)
    cin = 16
    for s, cout in enumerate(RESNET20_STAGES):
        for blk in range(RESNET20_BLOCKS_PER_STAGE):
            stride = 2 if (s > 0 and blk == 0) else 1
            shortcut = y
            h1 = conv_layer(y, nxt(), nxt(), k=3, simd=simd, stride=stride)
            h2 = conv_layer(h1, nxt(), nxt(), k=3, simd=simd, relu=False)
            if stride == 2:
                # option-A shortcut: subsample and zero-pad channels
                shortcut = shortcut[:, :, ::2, ::2]
                padc = cout - cin
                shortcut = jnp.pad(shortcut, ((0, 0), (0, padc), (0, 0), (0, 0)))
            y = relu_i16(sat_add_i16(h2, shortcut))
            cin = cout
    feat = avgpool_all(y, qf_shift=6)  # 8x8 = 64 = 2^6
    return dense_i16(feat, nxt(), nxt(), relu=False)


# --------------------------------------------------------------------------
# Face-detection cascade (Li et al. [29], stages 12-net and 24-net) — §IV-B
# --------------------------------------------------------------------------

def facedet_12net_param_shapes():
    return [
        ("conv.w", (16, 1, 3, 3)),
        ("conv.b", (16,)),
        ("fc1.w", (16, 16 * 5 * 5)),
        ("fc1.b", (16,)),
        ("fc2.w", (2, 16)),
        ("fc2.b", (2,)),
    ]


def facedet_12net(x, cw, cb, f1w, f1b, f2w, f2b, *, simd: int = 4):
    """12-net: x (B, 1, 12, 12) i16 → (B, 2) logits."""
    y = conv_layer(x, cw, cb, k=3, simd=simd, same=False, pool=True)  # (B,16,5,5)
    y = y.reshape(y.shape[0], -1)
    y = dense_i16(y, f1w, f1b)
    return dense_i16(y, f2w, f2b, relu=False)


def facedet_24net_param_shapes():
    # Sized so all 24-net parameters fit the 192 kB L2 alongside the 12-net
    # (§IV-B: "the CNN does not use any external memory and can rely
    # exclusively on the internal L2"): conv 3.2 kB + fc1 102.4 kB + fc2
    # 128 B ≈ 106 kB.
    return [
        ("conv.w", (64, 1, 5, 5)),
        ("conv.b", (64,)),
        ("fc1.w", (32, 64 * 5 * 5)),
        ("fc1.b", (32,)),
        ("fc2.w", (2, 32)),
        ("fc2.b", (2,)),
    ]


def facedet_24net(x, cw, cb, f1w, f1b, f2w, f2b, *, simd: int = 4):
    """24-net: x (B, 1, 24, 24) i16 → (B, 2) logits."""
    y = conv_layer(x, cw, cb, k=5, simd=simd, same=False, pool=True)  # (B,64,10,10)
    y = maxpool2x2(y)  # (B,64,5,5)
    y = y.reshape(y.shape[0], -1)
    y = dense_i16(y, f1w, f1b)
    return dense_i16(y, f2w, f2b, relu=False)


# --------------------------------------------------------------------------
# Quickstart: one small HWCE layer
# --------------------------------------------------------------------------

def quickstart_conv(x, w, b, *, simd: int = 4):
    """x (1, 4, 16, 16), w (8, 4, 3, 3), b (8) → (1, 8, 16, 16)."""
    return conv_layer(x, w, b, k=3, simd=simd)


# --------------------------------------------------------------------------
# Artifact registry: name -> (fn, example ShapeDtypeStructs, metadata)
# --------------------------------------------------------------------------

def _i16(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int16)


def _specs(shapes):
    return [_i16(s) for _, s in shapes]


def artifact_registry():
    """All AOT artifacts: name -> (jittable fn, example args, metadata)."""
    reg = {}

    # quickstart (w4 weights: range [-8, 7])
    reg["quickstart_conv_w4"] = (
        functools.partial(quickstart_conv, simd=4),
        [_i16((1, 4, 16, 16)), _i16((8, 4, 3, 3)), _i16((8,))],
        {"kind": "conv", "k": 3, "simd": 4, "qf": QF},
    )

    # single-layer artifacts used by the layer-level cross-check tests
    reg["hwce_conv3_w16"] = (
        functools.partial(lambda x, w, y: hwce_layer(x, w, y, k=3, qf=QF, simd=1)),
        [_i16((1, 4, 18, 18)), _i16((8, 4, 3, 3)), _i16((1, 8, 16, 16))],
        {"kind": "hwce_raw", "k": 3, "simd": 1, "qf": QF},
    )
    reg["hwce_conv5_w4"] = (
        functools.partial(lambda x, w, y: hwce_layer(x, w, y, k=5, qf=QF, simd=4)),
        [_i16((1, 2, 20, 20)), _i16((8, 2, 5, 5)), _i16((1, 8, 16, 16))],
        {"kind": "hwce_raw", "k": 5, "simd": 4, "qf": QF},
    )

    # ResNet-20 (B=1), 4-bit weight mode (the §IV-A headline configuration)
    rn_shapes = resnet20_param_shapes()
    reg["resnet20_cifar_w4"] = (
        functools.partial(resnet20, simd=4),
        [_i16((1, 3, 32, 32))] + _specs(rn_shapes),
        {"kind": "resnet20", "k": 3, "simd": 4, "qf": QF,
         "params": [(n, list(s)) for n, s in rn_shapes]},
    )

    # Face-detection nets, batched over 16 windows
    fd12 = facedet_12net_param_shapes()
    reg["facedet_12net_w4"] = (
        functools.partial(facedet_12net, simd=4),
        [_i16((16, 1, 12, 12))] + _specs(fd12),
        {"kind": "facedet12", "k": 3, "simd": 4, "qf": QF,
         "params": [(n, list(s)) for n, s in fd12]},
    )
    fd24 = facedet_24net_param_shapes()
    reg["facedet_24net_w4"] = (
        functools.partial(facedet_24net, simd=4),
        [_i16((16, 1, 24, 24))] + _specs(fd24),
        {"kind": "facedet24", "k": 5, "simd": 4, "qf": QF,
         "params": [(n, list(s)) for n, s in fd24]},
    )

    return reg


# Deterministic parameter generation shared (by formula) with the rust side.

def xorshift_i16(seed: int, n: int, lo: int, hi: int) -> np.ndarray:
    """Deterministic xorshift64 stream mapped into [lo, hi] — the exact
    algorithm is mirrored in rust/src/apps/params.rs; keep in sync."""
    out = np.empty(n, dtype=np.int64)
    x = np.uint64(seed | 1)
    span = np.uint64(hi - lo + 1)
    for i in range(n):
        x ^= np.uint64((x << np.uint64(13)) & np.uint64(0xFFFFFFFFFFFFFFFF))
        x ^= x >> np.uint64(7)
        x ^= np.uint64((x << np.uint64(17)) & np.uint64(0xFFFFFFFFFFFFFFFF))
        out[i] = int(x % span) + lo
    return out.astype(np.int16)


def gen_params(shapes, simd: int, seed: int = 1):
    """Generate deterministic in-range parameters for the given shapes."""
    lo_w, hi_w = {1: (-256, 255), 2: (-128, 127), 4: (-8, 7)}[simd]
    params = []
    for i, (name, shape) in enumerate(shapes):
        n = int(np.prod(shape))
        if name.endswith(".b"):
            vals = xorshift_i16(seed + 1000 + i, n, -64, 64)
        elif "fc" in name:
            vals = xorshift_i16(seed + 1000 + i, n, -16, 16)
        else:
            vals = xorshift_i16(seed + 1000 + i, n, lo_w, hi_w)
        params.append(vals.reshape(shape))
    return params
