"""L1: Pallas kernel mirroring the Fulmine HWCE datapath (paper §II-C).

Semantics contract (bit-exact with ``rust/src/hwce/golden.rs`` and
``ref.py``):

* pixels ``x`` and memory-resident partial sums ``y`` are int16 fixed-point
  with ``qf`` fractional bits;
* weights are int16 values constrained to the precision mode's range
  (full int16 / [-128,127] / [-8,7] for the 16/8/4-bit modes);
* one *pass* (one input channel) computes, per concurrent output map f:
  ``y[f] = sat16(y[f] + round(sum_window(x * w[f]) >> qf))``
  with exact wide accumulation, round-to-nearest normalization and int16
  saturation — the "fractional part normalization and saturation" stage of
  the HWCE second-level reduction tree (Fig. 5).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the HWCE line buffer
becomes a VMEM-resident x block whose window reuse is expressed by the
k*k shifted-slice accumulation below; the 1/2/4-outputs-per-pass precision
scaling becomes the ``simd`` leading axis of the weight/output blocks; the
input-channel accumulation that the silicon performs through the shared
TCDM becomes grid-axis revisiting of the output block (the block persists
across the ``cin`` grid axis and accumulates in place).

``interpret=True`` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret-mode lowering produces plain HLO that both jax and
the rust runtime execute identically.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# int16 fixed-point bounds (HWCE write-back saturation).
I16_MIN = -32768
I16_MAX = 32767


def _norm_round(acc, qf: int):
    """Round-to-nearest arithmetic normalization: (acc + 2^(qf-1)) >> qf.

    ``acc`` must be a signed integer array wide enough not to overflow
    (int64 — products of int16 summed over k*k taps need ~37 bits).
    """
    if qf == 0:
        return acc
    half = jnp.int64(1 << (qf - 1))
    return (acc + half) >> qf


def _sat16(v):
    return jnp.clip(v, I16_MIN, I16_MAX).astype(jnp.int16)


def _conv_kernel(x_ref, w_ref, yin_ref, out_ref, *, k: int, qf: int, simd: int):
    """One (batch, cof-group, cin) grid step.

    Block shapes:
      x_ref:   (1, 1, H, W)        int16 — input channel `cin` of batch b
      w_ref:   (1, simd, 1, k, k)  int16 — taps for the simd concurrent maps
      yin_ref: (1, simd, OH, OW)   int16 — initial partial sums (used once)
      out_ref: (1, simd, OH, OW)   int16 — revisited across the cin axis
    """
    cin = pl.program_id(2)
    n_cin = pl.num_programs(2)
    del n_cin  # documented for clarity; accumulation is per-step

    x = x_ref[0, 0].astype(jnp.int64)  # (H, W)
    h, w = x.shape
    oh, ow = h - k + 1, w - k + 1

    # First cin step seeds the output block with y_in (the memory-resident
    # partial sums of the silicon design).
    @pl.when(cin == 0)
    def _seed():
        out_ref[...] = yin_ref[...]

    # Sum-of-products via k*k shifted slices (line-buffer window reuse).
    acc = jnp.zeros((simd, oh, ow), dtype=jnp.int64)
    for f in range(simd):
        wf = w_ref[0, f, 0].astype(jnp.int64)  # (k, k)
        a = jnp.zeros((oh, ow), dtype=jnp.int64)
        for ky in range(k):
            for kx in range(k):
                a = a + x[ky : ky + oh, kx : kx + ow] * wf[ky, kx]
        acc = acc.at[f].set(a)

    contrib = _norm_round(acc, qf)
    prev = out_ref[0].astype(jnp.int64)
    out_ref[0, ...] = _sat16(prev + contrib)


@functools.partial(
    jax.jit, static_argnames=("k", "qf", "simd")
)
def hwce_layer(x, w, y_in, *, k: int, qf: int, simd: int):
    """Full multi-channel HWCE layer: accumulate all input channels.

    Args:
      x:    (B, Cin, H, W) int16
      w:    (Cout, Cin, k, k) int16, Cout % simd == 0, values within the
            precision mode's range (validated at build/test time, not traced)
      y_in: (B, Cout, OH, OW) int16 — usually the broadcast bias or zeros
    Returns:
      (B, Cout, OH, OW) int16
    """
    b, cin, h, ww = x.shape
    cout = w.shape[0]
    assert cout % simd == 0, "Cout must be a multiple of the simd factor"
    assert w.shape[1] == cin and w.shape[2] == k and w.shape[3] == k
    oh, ow = h - k + 1, ww - k + 1
    assert y_in.shape == (b, cout, oh, ow)

    grid = (b, cout // simd, cin)
    return pl.pallas_call(
        functools.partial(_conv_kernel, k=k, qf=qf, simd=simd),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, h, ww), lambda bb, co, ci: (bb, ci, 0, 0)),
            pl.BlockSpec((1, simd, 1, k, k), lambda bb, co, ci: (0, co, ci, 0, 0)),
            pl.BlockSpec((1, simd, oh, ow), lambda bb, co, ci: (bb, co, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, simd, oh, ow), lambda bb, co, ci: (bb, co, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, cout, oh, ow), jnp.int16),
        interpret=True,
    )(x, w.reshape(1, cout, cin, k, k), y_in)


def sat_add_i16(a, b):
    """Saturating int16 add (bias / residual), matching fixedpoint::add_sat."""
    s = a.astype(jnp.int32) + b.astype(jnp.int32)
    return jnp.clip(s, I16_MIN, I16_MAX).astype(jnp.int16)


def relu_i16(a):
    return jnp.maximum(a, jnp.int16(0))
