"""Pure-numpy oracle for the HWCE kernel — the correctness reference.

Implements exactly the semantics contract of ``hwce.py`` (and of the rust
golden model) without Pallas: per input channel, a valid k*k correlation,
round-to-nearest normalization by ``qf``, and saturating accumulation onto
the int16 partial-sum array. Used by the pytest suite to validate the
Pallas kernel over randomized shapes/values (hypothesis sweeps).
"""

import numpy as np

I16_MIN = -32768
I16_MAX = 32767


def norm_round(acc: np.ndarray, qf: int) -> np.ndarray:
    if qf == 0:
        return acc
    return (acc + (1 << (qf - 1))) >> qf


def sat16(v: np.ndarray) -> np.ndarray:
    return np.clip(v, I16_MIN, I16_MAX).astype(np.int16)


def hwce_pass_ref(x, w, y, k: int, qf: int):
    """One pass: x (H, W) i16, w (k, k) i16, y (OH, OW) i16 (updated copy)."""
    x = x.astype(np.int64)
    w = w.astype(np.int64)
    oh, ow = x.shape[0] - k + 1, x.shape[1] - k + 1
    acc = np.zeros((oh, ow), dtype=np.int64)
    for ky in range(k):
        for kx in range(k):
            acc += x[ky : ky + oh, kx : kx + ow] * w[ky, kx]
    contrib = norm_round(acc, qf)
    return sat16(y.astype(np.int64) + contrib)


def hwce_layer_ref(x, w, y_in, k: int, qf: int):
    """Reference multi-channel layer.

    x (B, Cin, H, W) i16, w (Cout, Cin, k, k) i16, y_in (B, Cout, OH, OW) i16.
    Channel passes are applied sequentially (normalize/saturate per pass),
    matching the HWCE's memory-resident accumulation order.
    """
    b, cin, _, _ = x.shape
    cout = w.shape[0]
    out = y_in.copy()
    for bb in range(b):
        for co in range(cout):
            acc = out[bb, co]
            for ci in range(cin):
                acc = hwce_pass_ref(x[bb, ci], w[co, ci], acc, k, qf)
            out[bb, co] = acc
    return out


def sat_add_i16_ref(a, b):
    return sat16(a.astype(np.int64) + b.astype(np.int64))


def relu_i16_ref(a):
    return np.maximum(a, 0).astype(np.int16)


def weight_range(simd: int):
    """Weight value range per precision mode (simd factor 1/2/4)."""
    return {1: (I16_MIN, I16_MAX), 2: (-128, 127), 4: (-8, 7)}[simd]
