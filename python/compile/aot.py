"""AOT driver: lower every artifact in the registry to HLO *text*.

HLO text (not ``.serialize()``) is the interchange format: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts``  (from python/)
The Makefile target ``artifacts`` invokes this once; python never runs on
the request path.
"""

import argparse
import json
import os

import jax

# int64 accumulators in the kernels require x64 mode (set before any trace).
jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from compile.model import QF, artifact_registry  # noqa: E402


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", default=None, help="build a single artifact")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {"qf": QF, "artifacts": {}}
    for name, (fn, specs, meta) in sorted(artifact_registry().items()):
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs],
            **meta,
        }
        print(f"wrote {path} ({len(text)} chars, {len(specs)} inputs)")

    mpath = os.path.join(args.out, "manifest.json")
    if args.only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {mpath}")

    # Line-oriented manifest for the rust runtime (the offline crate set has
    # no JSON parser): name|file|kind|k|simd|qf|shape,shape,...
    tpath = os.path.join(args.out, "manifest.txt")
    with open(tpath, "w") as f:
        for name, meta in sorted(manifest["artifacts"].items()):
            shapes = ";".join(
                "x".join(str(d) for d in inp["shape"]) or "scalar"
                for inp in meta["inputs"]
            )
            f.write(
                f"{name}|{meta['file']}|{meta['kind']}|{meta['k']}|"
                f"{meta['simd']}|{meta['qf']}|{shapes}\n"
            )
    print(f"wrote {tpath}")


if __name__ == "__main__":
    main()
