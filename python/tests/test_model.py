"""L2 model tests: shapes, fixed-point semantics, determinism, and a
numpy re-implementation cross-check of the composite layers."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest

from compile import model
from compile.kernels import ref


def test_resnet20_param_shapes_contract():
    shapes = model.resnet20_param_shapes()
    # conv1(2) + 9 blocks × 4 + fc(2) = 40 parameter tensors
    assert len(shapes) == 40
    assert shapes[0] == ("conv1.w", (16, 3, 3, 3))
    assert shapes[-1] == ("fc.b", (10,))
    # total 16-bit weight footprint is in the expected regime (~0.27 MB for
    # CIFAR ResNet-20; the paper's 8.9 MB is the 224x224 variant with more
    # channels — checked in the rust apps module)
    total = sum(int(np.prod(s)) for _, s in shapes)
    assert 250_000 < total < 300_000


def test_resnet20_forward_shape_and_determinism():
    params = model.gen_params(model.resnet20_param_shapes(), simd=4, seed=3)
    x = model.gen_params([("x", (1, 3, 32, 32))], simd=1, seed=9)[0]
    y1 = np.asarray(model.resnet20(x, *params, simd=4))
    y2 = np.asarray(model.resnet20(x, *params, simd=4))
    assert y1.shape == (1, 10)
    assert y1.dtype == np.int16
    np.testing.assert_array_equal(y1, y2)
    assert np.any(y1 != 0), "logits must not be all zero"


def test_facedet_shapes():
    p12 = model.gen_params(model.facedet_12net_param_shapes(), simd=4, seed=5)
    x12 = model.gen_params([("x", (16, 1, 12, 12))], simd=1, seed=6)[0]
    y = np.asarray(model.facedet_12net(x12, *p12, simd=4))
    assert y.shape == (16, 2) and y.dtype == np.int16

    p24 = model.gen_params(model.facedet_24net_param_shapes(), simd=4, seed=7)
    x24 = model.gen_params([("x", (16, 1, 24, 24))], simd=1, seed=8)[0]
    y = np.asarray(model.facedet_24net(x24, *p24, simd=4))
    assert y.shape == (16, 2) and y.dtype == np.int16


def test_conv_layer_matches_numpy_composition():
    rng = np.random.default_rng(11)
    x = rng.integers(-512, 512, size=(1, 2, 8, 8)).astype(np.int16)
    w = rng.integers(-8, 8, size=(4, 2, 3, 3)).astype(np.int16)
    b = rng.integers(-32, 32, size=(4,)).astype(np.int16)

    got = np.asarray(model.conv_layer(x, w, b, k=3, simd=4))

    xp = np.pad(x, ((0, 0), (0, 0), (1, 1), (1, 1)))
    yin = np.zeros((1, 4, 8, 8), dtype=np.int16)
    conv = ref.hwce_layer_ref(xp, w, yin, k=3, qf=model.QF)
    want = ref.relu_i16_ref(ref.sat_add_i16_ref(conv, b[None, :, None, None]))
    np.testing.assert_array_equal(got, want)


def test_conv_layer_stride2_is_dense_then_subsample():
    rng = np.random.default_rng(12)
    x = rng.integers(-512, 512, size=(1, 1, 10, 10)).astype(np.int16)
    w = rng.integers(-8, 8, size=(4, 1, 3, 3)).astype(np.int16)
    b = np.zeros(4, dtype=np.int16)
    full = np.asarray(model.conv_layer(x, w, b, k=3, simd=4))
    strided = np.asarray(model.conv_layer(x, w, b, k=3, simd=4, stride=2))
    np.testing.assert_array_equal(strided, full[:, :, ::2, ::2])


def test_maxpool_and_avgpool():
    x = np.arange(16, dtype=np.int16).reshape(1, 1, 4, 4)
    p = np.asarray(model.maxpool2x2(x))
    np.testing.assert_array_equal(p[0, 0], [[5, 7], [13, 15]])
    a = np.asarray(model.avgpool_all(x.astype(np.int16), qf_shift=4))
    assert a.shape == (1, 1)
    # sum = 120, (120 + 8) >> 4 = 8
    assert a[0, 0] == 8


def test_dense_i16_matches_numpy():
    rng = np.random.default_rng(13)
    x = rng.integers(-256, 256, size=(2, 8)).astype(np.int16)
    w = rng.integers(-16, 16, size=(3, 8)).astype(np.int16)
    b = rng.integers(-8, 8, size=(3,)).astype(np.int16)
    got = np.asarray(model.dense_i16(x, w, b, qf=4, relu=False))
    acc = x.astype(np.int64) @ w.astype(np.int64).T
    want = ref.sat16(((acc + 8) >> 4) + b[None, :])
    np.testing.assert_array_equal(got, want)


def test_gen_params_respects_precision_ranges():
    shapes = [("conv.w", (8, 2, 3, 3)), ("conv.b", (8,))]
    for simd, (lo, hi) in [(4, (-8, 7)), (2, (-128, 127))]:
        w, b = model.gen_params(shapes, simd=simd, seed=1)
        assert w.min() >= lo and w.max() <= hi
        assert b.dtype == np.int16


def test_artifact_registry_complete():
    reg = model.artifact_registry()
    expected = {
        "quickstart_conv_w4",
        "hwce_conv3_w16",
        "hwce_conv5_w4",
        "resnet20_cifar_w4",
        "facedet_12net_w4",
        "facedet_24net_w4",
    }
    assert expected <= set(reg.keys())
    for name, (fn, specs, meta) in reg.items():
        assert callable(fn), name
        assert all(s.dtype == np.int16 for s in specs), name
        assert "qf" in meta, name


def test_xorshift_contract_values():
    """Pin the first few xorshift values — the rust side must generate the
    identical stream (rust/src/apps/params.rs)."""
    v = model.xorshift_i16(1, 4, -8, 7)
    x = np.uint64(1)
    expect = []
    for _ in range(4):
        x ^= np.uint64((x << np.uint64(13)) & np.uint64(0xFFFFFFFFFFFFFFFF))
        x ^= x >> np.uint64(7)
        x ^= np.uint64((x << np.uint64(17)) & np.uint64(0xFFFFFFFFFFFFFFFF))
        expect.append(int(x % np.uint64(16)) - 8)
    np.testing.assert_array_equal(v, expect)
