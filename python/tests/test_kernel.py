"""Pallas HWCE kernel vs. the pure-numpy oracle — the core L1 correctness
signal. Includes hypothesis sweeps over shapes, precisions and Q-formats."""

import jax

jax.config.update("jax_enable_x64", True)

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.hwce import hwce_layer, relu_i16, sat_add_i16


def rnd_i16(rng, shape, lo, hi):
    return rng.integers(lo, hi + 1, size=shape, dtype=np.int64).astype(np.int16)


def run_both(rng, b, cin, cout, h, w, k, qf, simd, wlo, whi):
    x = rnd_i16(rng, (b, cin, h, w), -2048, 2047)
    wt = rnd_i16(rng, (cout, cin, k, k), wlo, whi)
    yin = rnd_i16(rng, (b, cout, h - k + 1, w - k + 1), -1024, 1023)
    got = np.asarray(hwce_layer(x, wt, yin, k=k, qf=qf, simd=simd))
    want = ref.hwce_layer_ref(x, wt, yin, k=k, qf=qf)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("k", [3, 5])
@pytest.mark.parametrize("simd", [1, 2, 4])
def test_kernel_matches_ref_basic(k, simd):
    rng = np.random.default_rng(42 + k + simd)
    wlo, whi = ref.weight_range(simd)
    run_both(rng, b=1, cin=3, cout=simd * 2, h=12, w=10, k=k, qf=8,
             simd=simd, wlo=wlo, whi=whi)


def test_kernel_batched():
    rng = np.random.default_rng(7)
    run_both(rng, b=3, cin=2, cout=4, h=9, w=9, k=3, qf=8, simd=4, wlo=-8, whi=7)


def test_kernel_qf_zero():
    rng = np.random.default_rng(8)
    run_both(rng, b=1, cin=1, cout=1, h=8, w=8, k=3, qf=0, simd=1,
             wlo=-3, whi=3)


def test_saturation_matches():
    # drive accumulators into saturation on both paths
    x = np.full((1, 1, 7, 7), 32767, dtype=np.int16)
    wt = np.full((1, 1, 3, 3), 32767, dtype=np.int16)
    yin = np.full((1, 1, 5, 5), 32000, dtype=np.int16)
    got = np.asarray(hwce_layer(x, wt, yin, k=3, qf=0, simd=1))
    want = ref.hwce_layer_ref(x, wt, yin, k=3, qf=0)
    np.testing.assert_array_equal(got, want)
    assert got.max() == 32767


def test_negative_rounding_matches():
    # values chosen to hit the round-half boundary on negatives
    x = np.full((1, 1, 5, 5), -1, dtype=np.int16)
    wt = np.ones((1, 1, 3, 3), dtype=np.int16)
    yin = np.zeros((1, 1, 3, 3), dtype=np.int16)
    got = np.asarray(hwce_layer(x, wt, yin, k=3, qf=4, simd=1))
    want = ref.hwce_layer_ref(x, wt, yin, k=3, qf=4)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    k=st.sampled_from([3, 5]),
    simd=st.sampled_from([1, 2, 4]),
    qf=st.integers(min_value=0, max_value=12),
    cin=st.integers(min_value=1, max_value=4),
    groups=st.integers(min_value=1, max_value=2),
    h=st.integers(min_value=6, max_value=16),
    w=st.integers(min_value=6, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_kernel_matches_ref_hypothesis(k, simd, qf, cin, groups, h, w, seed):
    if h < k + 1 or w < k + 1:
        return
    rng = np.random.default_rng(seed)
    wlo, whi = ref.weight_range(simd)
    run_both(rng, b=1, cin=cin, cout=simd * groups, h=h, w=w, k=k, qf=qf,
             simd=simd, wlo=wlo, whi=whi)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31))
def test_elementwise_helpers_match(seed):
    rng = np.random.default_rng(seed)
    a = rnd_i16(rng, (64,), -32768, 32767)
    b = rnd_i16(rng, (64,), -32768, 32767)
    np.testing.assert_array_equal(
        np.asarray(sat_add_i16(a, b)), ref.sat_add_i16_ref(a, b))
    np.testing.assert_array_equal(
        np.asarray(relu_i16(a)), ref.relu_i16_ref(a))
