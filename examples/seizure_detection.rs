//! §IV-C example: EEG seizure detection with secure long-term monitoring.
//!
//! A synthetic 23-channel EEG stream (with seizure segments injected at
//! known windows) runs through the functional PCA→DWT→SVM pipeline; the
//! PCA components of every window are protected with the KECCAK-f[400]
//! sponge authenticated-encryption scheme before "transmission", and a
//! tampered record is shown to fail authentication. Ends with the Fig. 12
//! ladder from the simulated SoC.
//!
//! Run: `cargo run --release --example seizure_detection`

use fulmine::apps::eeg;
use fulmine::crypto::sponge::{ae_decrypt, ae_encrypt, SpongeConfig};
use fulmine::kernels_sw::eeg_cost::DWT_LEVELS;
use fulmine::report;

fn main() {
    let key = [0x77u8; 16];
    let n_windows = 20;
    // ground truth: seizures injected in windows 7..10
    let is_seizure = |i: usize| (7..10).contains(&i);

    let mut detected = Vec::new();
    let mut records: Vec<(Vec<u8>, [u8; 16], [u8; 16])> = Vec::new();
    for i in 0..n_windows {
        let win = eeg::synth_window(1000 + i as u64, is_seizure(i));
        let (seizure, comps) = eeg::detect(&win, DWT_LEVELS);
        detected.push(seizure);

        // secure collection: quantize components to i16, sponge-AE encrypt
        let payload: Vec<u8> = comps
            .iter()
            .flat_map(|c| c.iter().map(|&v| v.clamp(-32768, 32767) as i16))
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let mut iv = [0u8; 16];
        iv[..8].copy_from_slice(&(i as u64).to_le_bytes());
        let (ct, tag) = ae_encrypt(SpongeConfig::MAX_RATE, &key, &iv, &payload);
        records.push((ct, tag, iv));
    }

    let tp = (0..n_windows).filter(|&i| is_seizure(i) && detected[i]).count();
    let fp = (0..n_windows).filter(|&i| !is_seizure(i) && detected[i]).count();
    println!("windows: {n_windows}, seizure windows: 3");
    println!("detected: {tp}/3 true positives, {fp} false positives");
    assert_eq!(tp, 3, "all injected seizures must be detected");
    assert_eq!(fp, 0, "no false alarms on background EEG");

    // collector side: verify + decrypt one record
    let (ct, tag, iv) = &records[8];
    let plain = ae_decrypt(SpongeConfig::MAX_RATE, &key, iv, ct, tag)
        .expect("authentic record must decrypt");
    println!(
        "record 8 authenticated & decrypted: {} bytes of PCA components",
        plain.len()
    );

    // a tampered record must be rejected by the prefix MAC
    let mut bad = ct.clone();
    bad[17] ^= 0x01;
    assert!(
        ae_decrypt(SpongeConfig::MAX_RATE, &key, iv, &bad, tag).is_none(),
        "tampered record must fail authentication"
    );
    println!("tampered record rejected by sponge MAC ✓\n");

    println!("=== Fig. 12 — simulated Fulmine SoC ===\n");
    print!("{}", report::fig12());
}
