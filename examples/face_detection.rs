//! §IV-B example: local face detection with secured remote recognition.
//!
//! A synthetic 224×224 frame is tiled into 12×12 windows; the 12-net AOT
//! artifact screens batches of 16 windows; candidate regions go to the
//! 24-net; on detection, the full frame is AES-128-XTS encrypted for
//! transmission to the paired device (only ciphertext ever leaves the SoC).
//! Ends with the Fig. 11 ladder from the simulated SoC.
//!
//! Run: `cargo run --release --example face_detection`

use anyhow::Result;
use fulmine::apps::params::{gen_params, xorshift_i16};
use fulmine::crypto::modes::XtsKey;
use fulmine::report;
use fulmine::runtime::{default_artifact_dir, Runtime, TensorI16};

const FRAME: usize = 224;

/// Synthetic frame: background noise plus a bright blob ("face") whose
/// windows score differently through the deterministic nets.
fn synth_frame() -> Vec<i16> {
    let mut img = xorshift_i16(4242, FRAME * FRAME, -200, 200);
    for y in 60..120 {
        for x in 90..150 {
            let dy = y as i32 - 90;
            let dx = x as i32 - 120;
            if dy * dy + dx * dx < 900 {
                img[y * FRAME + x] = img[y * FRAME + x].saturating_add(1500);
            }
        }
    }
    img
}

fn window(img: &[i16], wy: usize, wx: usize, n: usize) -> Vec<i16> {
    let mut out = Vec::with_capacity(n * n);
    for y in 0..n {
        out.extend_from_slice(&img[(wy + y) * FRAME + wx..][..n]);
    }
    out
}

fn main() -> Result<()> {
    let mut rt = Runtime::open(default_artifact_dir())?;
    let m12 = rt.meta("facedet_12net_w4").expect("run `make artifacts`").clone();
    let p12 = gen_params(&m12.input_shapes[1..], m12.simd, 5);
    let m24 = rt.meta("facedet_24net_w4").unwrap().clone();
    let p24 = gen_params(&m24.input_shapes[1..], m24.simd, 7);

    let img = synth_frame();
    let tiles = FRAME / 12; // 18×18 non-overlapping windows
    let mut candidates: Vec<(usize, usize, i16)> = Vec::new();

    // Stage 1: 12-net over all windows, in batches of 16 (the artifact's
    // static batch dimension).
    let mut batch: Vec<(usize, usize)> = Vec::new();
    let mut flush = |batch: &mut Vec<(usize, usize)>,
                     rt: &mut Runtime,
                     candidates: &mut Vec<(usize, usize, i16)>|
     -> Result<()> {
        if batch.is_empty() {
            return Ok(());
        }
        let mut data = Vec::with_capacity(16 * 144);
        for &(wy, wx) in batch.iter() {
            data.extend(window(&img, wy * 12, wx * 12, 12));
        }
        data.resize(16 * 144, 0);
        let x = TensorI16::new(vec![16, 1, 12, 12], data);
        let mut inp = vec![x];
        inp.extend(p12.clone());
        let out = rt.execute("facedet_12net_w4", &inp)?;
        for (i, &(wy, wx)) in batch.iter().enumerate() {
            let score = out[0].data[i * 2].saturating_sub(out[0].data[i * 2 + 1]);
            candidates.push((wy, wx, score));
        }
        batch.clear();
        Ok(())
    };
    for wy in 0..tiles {
        for wx in 0..tiles {
            batch.push((wy, wx));
            if batch.len() == 16 {
                flush(&mut batch, &mut rt, &mut candidates)?;
            }
        }
    }
    flush(&mut batch, &mut rt, &mut candidates)?;
    println!("12-net screened {} windows", candidates.len());

    // Top 10 % of windows by score go to the 24-net.
    candidates.sort_by_key(|&(_, _, s)| std::cmp::Reverse(s));
    let n2 = candidates.len() / 10;
    let stage2 = &candidates[..n2.max(1)];
    println!("stage 2: {} candidate windows", stage2.len());

    let mut best: Option<(usize, usize, i16)> = None;
    for chunk in stage2.chunks(16) {
        let mut data = Vec::with_capacity(16 * 576);
        for &(wy, wx, _) in chunk {
            let cy = (wy * 12).min(FRAME - 24);
            let cx = (wx * 12).min(FRAME - 24);
            data.extend(window(&img, cy, cx, 24));
        }
        data.resize(16 * 576, 0);
        let x = TensorI16::new(vec![16, 1, 24, 24], data);
        let mut inp = vec![x];
        inp.extend(p24.clone());
        let out = rt.execute("facedet_24net_w4", &inp)?;
        for (i, &(wy, wx, _)) in chunk.iter().enumerate() {
            let s = out[0].data[i * 2].saturating_sub(out[0].data[i * 2 + 1]);
            if best.map(|(_, _, bs)| s > bs).unwrap_or(true) {
                best = Some((wy, wx, s));
            }
        }
    }
    let (by, bx, bs) = best.unwrap();
    println!("24-net best window: ({by},{bx}) score {bs} → face candidate");

    // Detection → encrypt the full frame for remote recognition.
    let key = XtsKey::new(&[9; 16], &[3; 16]);
    let frame_bytes: Vec<u8> = img.iter().flat_map(|v| v.to_le_bytes()).collect();
    let ct = fulmine::crypto::modes::xts_encrypt_region(&key, 0, 512, &frame_bytes);
    assert_ne!(&ct[..64], &frame_bytes[..64]);
    println!("frame encrypted for transmission: {} bytes ciphertext\n", ct.len());

    println!("=== Fig. 11 — simulated Fulmine SoC ===\n");
    print!("{}", report::fig11());
    Ok(())
}
