//! End-to-end driver (§IV-A): secure CNN inference with every layer of the
//! stack composed — the repository's full-system validation.
//!
//! Functional path (real computation, CIFAR-scale ResNet-20):
//!   1. generate deterministic ResNet-20 parameters (the "trained" weights);
//!   2. AES-128-XTS-encrypt them into the simulated external flash — the
//!      cluster is the only place where plaintext may live;
//!   3. capture a synthetic camera frame, stage it, decrypt the weights,
//!      and run the *whole network* through the AOT-compiled XLA artifact
//!      (Pallas HWCE kernels lowered to HLO, executed via PJRT);
//!   4. verify the logits are bit-identical to a second run and that a
//!      tampered flash image corrupts (never silently alters) the result;
//!   5. classify a small batch of frames and report throughput.
//!
//! Timing/energy path (the paper's 224×224 workload): the simulated SoC
//! executes the Fig. 10 ladder and reports time, energy, breakdown and
//! pJ/op — the numbers recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example secure_surveillance`

use anyhow::Result;
use fulmine::apps::params::{gen_params, xorshift_i16};
use fulmine::coordinator::surveillance;
use fulmine::crypto::modes::XtsKey;
use fulmine::extmem::{Device, ExtMem};
use fulmine::report;
use fulmine::runtime::{default_artifact_dir, Runtime, TensorI16};

fn main() -> Result<()> {
    println!("=== Fulmine secure surveillance: end-to-end functional run ===\n");
    let mut rt = Runtime::open(default_artifact_dir())?;
    let meta = rt.meta("resnet20_cifar_w4").expect("run `make artifacts`").clone();

    // 1. "trained" parameters, generated deterministically
    let params = gen_params(&meta.input_shapes[1..], meta.simd, 1);
    let total_weight_bytes: usize = params.iter().map(|p| p.bytes()).sum();
    println!("ResNet-20 parameters: {} tensors, {} bytes", params.len(), total_weight_bytes);

    // 2. encrypt into the simulated flash (sector-addressed XTS)
    let key = XtsKey::new(&[0xA5; 16], &[0x5A; 16]);
    let mut flash = ExtMem::new(Device::Flash);
    let blob: Vec<u8> = params.iter().flat_map(|p| p.to_bytes()).collect();
    let padded = {
        let mut b = blob.clone();
        b.resize(b.len().div_ceil(512) * 512, 0);
        b
    };
    flash.store_encrypted(&key, 0, &padded, None);
    assert_ne!(flash.raw(0, 64), &padded[..64], "flash must hold ciphertext");
    println!("weights encrypted into flash ({} sectors)", padded.len() / 512);

    // 3. decrypt inside the \"secure enclave\" and run the full network
    let plain = flash.load_decrypted(&key, 0, padded.len(), None);
    assert_eq!(&plain[..blob.len()], &blob[..], "decryption mismatch");
    let mut off = 0usize;
    let restored: Vec<TensorI16> = params
        .iter()
        .map(|p| {
            let t = TensorI16::from_bytes(p.shape.clone(), &plain[off..off + p.bytes()]);
            off += p.bytes();
            t
        })
        .collect();

    let frame = TensorI16::new(
        meta.input_shapes[0].clone(),
        xorshift_i16(99, meta.input_shapes[0].iter().product(), -2048, 2047),
    );
    let mut inputs = vec![frame.clone()];
    inputs.extend(restored);
    let t0 = std::time::Instant::now();
    let logits = rt.execute("resnet20_cifar_w4", &inputs)?;
    let dt = t0.elapsed();
    println!(
        "full ResNet-20 forward through PJRT in {:.1} ms → logits {:?}",
        dt.as_secs_f64() * 1e3,
        logits[0].data
    );
    let class = logits[0]
        .data
        .iter()
        .enumerate()
        .max_by_key(|(_, &v)| v)
        .unwrap()
        .0;
    println!("predicted class: {class}");

    // 4a. determinism
    let again = rt.execute("resnet20_cifar_w4", &inputs)?;
    assert_eq!(again[0], logits[0]);
    println!("re-run bit-identical ✓");

    // 4b. tamper detection: flip one flash bit, results must change
    flash.corrupt(1000, 0x80);
    let tampered = flash.load_decrypted(&key, 0, padded.len(), None);
    assert_ne!(&tampered[..blob.len()], &blob[..]);
    println!("flash tampering scrambles the decrypted weights ✓");

    // 5. small batch throughput
    let n = 5;
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let f = TensorI16::new(
            meta.input_shapes[0].clone(),
            xorshift_i16(100 + i, meta.input_shapes[0].iter().product(), -2048, 2047),
        );
        let mut inp = vec![f];
        inp.extend(inputs[1..].to_vec());
        rt.execute("resnet20_cifar_w4", &inp)?;
    }
    println!(
        "batch of {n} frames: {:.1} ms/frame on the host CPU\n",
        t0.elapsed().as_secs_f64() * 1e3 / n as f64
    );

    // --- the paper's 224×224 workload on the simulated SoC --------------
    println!("=== Fig. 10 — simulated Fulmine SoC, 224×224 secure ResNet-20 ===\n");
    print!("{}", report::fig10());
    let best = surveillance::ladder().into_iter().last().unwrap();
    println!(
        "\nheadline: {:.3} s / frame, {:.1} mJ, {:.2} pJ/op (paper: 27 mJ, 3.16 pJ/op)",
        best.time_s, best.energy_mj, best.pj_per_op
    );
    Ok(())
}
