//! Quickstart: the three-layer stack in one page.
//!
//! 1. Load an AOT HWCE convolution artifact (Pallas → HLO text, built once
//!    by `make artifacts`) through the PJRT runtime — no python at runtime.
//! 2. Run it on generated int16 fixed-point data.
//! 3. Cross-check one output pixel against the rust golden model.
//! 4. Protect the result with the HWCRYPT functional model (AES-128-XTS),
//!    and show what the simulated SoC says this costs in time and energy.
//! 5. Do the same through the first-class workload API: resolve a
//!    registered scenario by name, stream frames through the `SocSystem`
//!    façade, and render the structured report as text and JSON.
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;
use fulmine::apps::params::{gen_params, xorshift_i16};
use fulmine::coordinator::{ExecConfig, GraphBuilder};
use fulmine::crypto::modes::XtsKey;
use fulmine::soc::sched::Scheduler;
use fulmine::hwce::golden::WeightPrec;
use fulmine::runtime::{default_artifact_dir, Runtime, TensorI16};
use fulmine::system::{RunSpec, RungSel, SocSystem};

fn main() -> Result<()> {
    // --- 1. the AOT artifact --------------------------------------------
    let mut rt = Runtime::open(default_artifact_dir())?;
    let name = "quickstart_conv_w4";
    let meta = rt.meta(name).expect("run `make artifacts` first").clone();
    println!("artifact {name}: k={} simd={} qf={}", meta.k, meta.simd, meta.qf);

    // --- 2. int16 fixed-point inputs ------------------------------------
    let x = TensorI16::new(
        meta.input_shapes[0].clone(),
        xorshift_i16(42, meta.input_shapes[0].iter().product(), -1024, 1023),
    );
    let mut inputs = vec![x];
    inputs.extend(gen_params(&meta.input_shapes[1..], meta.simd, 7));
    let t0 = std::time::Instant::now();
    let out = rt.execute(name, &inputs)?;
    println!(
        "executed in {:.2} ms → output {:?}, sample {:?}",
        t0.elapsed().as_secs_f64() * 1e3,
        out[0].shape,
        &out[0].data[..8]
    );

    // --- 3. encrypt the result as the SoC would (HWCRYPT XTS) -----------
    let key = XtsKey::new(&[0x42; 16], &[0x24; 16]);
    let ct = fulmine::crypto::modes::xts_encrypt(&key, 0, &out[0].to_bytes());
    let rt_trip = fulmine::crypto::modes::xts_decrypt(&key, 0, &ct);
    assert_eq!(rt_trip, out[0].to_bytes());
    println!("XTS roundtrip of {} output bytes OK", ct.len());

    // --- 4. what would this cost on the Fulmine SoC? --------------------
    // Emit a two-job graph (convolve, then encrypt the result) and run it
    // through the event-driven SoC scheduler.
    let mut b = GraphBuilder::new(ExecConfig::with_hwce(WeightPrec::W4));
    let macs = 8 * 4 * 9 * 16 * 16; // cout·cin·k²·positions
    let conv = b.conv(macs as u64, 3, &[]);
    b.xts(out[0].bytes(), &[conv]);
    let res = Scheduler::run(&b.build());
    println!(
        "simulated on-SoC: {:.1} µs, {:.3} µJ ({})",
        res.makespan_s * 1e6,
        res.ledger.total_mj() * 1e3,
        "HWCE 4-bit + HWCRYPT @ 0.8 V"
    );

    // --- 5. the workload API: registered scenarios via the façade -------
    // Any registered workload streams by name; `mixed` interleaves one
    // frame of each paper use case per round on the same SoC, with
    // per-tenant energy attribution in the report.
    let sys = SocSystem::new();
    let run = sys.run(&RunSpec::new("mixed").frames(4).rung(RungSel::Best))?;
    print!("\n{}", run.render_text());
    println!("as JSON: {}", run.to_json().render());
    Ok(())
}
